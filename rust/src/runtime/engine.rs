//! PJRT execution engine: compiles the AOT HLO-text artifacts once and
//! executes them from the rust request path (no Python anywhere).
//!
//! Mirrors /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`. Executables
//! are shape-monomorphic, so tile lists are padded to the compiled K and
//! batched in groups of B tiles (DESIGN.md §Key design decisions #2).

use super::artifacts::{find_artifacts_dir, ArtifactManifest};
use crate::math::Vec3;
use crate::render::binning::TileBins;
use crate::render::framebuffer::{Frame, INVALID_DEPTH};
use crate::render::preprocess::Splat;
use crate::render::rasterize::VALID_ALPHA;
use crate::TILE;
use anyhow::{bail, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::Mutex;

/// PJRT engine: one CPU client + lazily compiled executables.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    pub manifest: ArtifactManifest,
    compiled: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtEngine {
    /// Create the engine, locating artifacts automatically when `dir` is
    /// None (see [`find_artifacts_dir`]).
    pub fn new(dir: Option<&Path>) -> Result<PjrtEngine> {
        let dir = find_artifacts_dir(dir)?;
        let manifest = ArtifactManifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtEngine {
            client,
            manifest,
            compiled: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch cached) an artifact by name.
    fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let entry = self
            .manifest
            .by_name(name)
            .with_context(|| format!("unknown artifact '{name}'"))?;
        let proto = xla::HloModuleProto::from_text_file(
            entry
                .path
                .to_str()
                .context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing {:?}", entry.path))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = std::sync::Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?,
        );
        self.compiled
            .lock()
            .unwrap()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Rasterize `tiles` (indices into the frame's tile grid) through the
    /// AOT kernel, writing color/alpha/depth/trunc/valid into `frame`.
    ///
    /// Tiles whose (already DPES-culled) list exceeds the largest compiled
    /// K are returned for the caller to fall back on the native path.
    pub fn render_tiles(
        &self,
        splats: &[Splat],
        bins: &TileBins,
        tiles: &[usize],
        frame: &mut Frame,
        background: Vec3,
    ) -> Result<Vec<usize>> {
        let variants = self.manifest.rasterize_variants();
        if variants.is_empty() {
            bail!("no rasterize artifacts in manifest");
        }
        let k_max = variants.last().unwrap().k;
        let mut overflow = Vec::new();
        let mut runnable: Vec<usize> = Vec::new();
        for &t in tiles {
            if bins.tile(t).len() > k_max {
                overflow.push(t);
            } else {
                runnable.push(t);
            }
        }
        // Group by required variant so each batch pads minimally, longest
        // lists first (better packing).
        runnable.sort_by_key(|&t| std::cmp::Reverse(bins.tile(t).len()));
        let b = variants[0].batch;
        for chunk in runnable.chunks(b) {
            let need = chunk.iter().map(|&t| bins.tile(t).len()).max().unwrap_or(0);
            let entry = self
                .manifest
                .rasterize_for(need)
                .expect("overflow filtered above");
            self.run_batch(entry.name.clone(), entry.batch, entry.k, splats, bins, chunk, frame, background)?;
        }
        Ok(overflow)
    }

    #[allow(clippy::too_many_arguments)]
    fn run_batch(
        &self,
        name: String,
        b: usize,
        k: usize,
        splats: &[Splat],
        bins: &TileBins,
        tiles: &[usize],
        frame: &mut Frame,
        background: Vec3,
    ) -> Result<()> {
        assert!(tiles.len() <= b);
        let (grid_x, _) = frame.tile_grid();
        let mut means = vec![0.0f32; b * k * 2];
        let mut conics = vec![0.0f32; b * k * 3];
        let mut colors = vec![0.0f32; b * k * 3];
        let mut opac = vec![0.0f32; b * k];
        let mut depths = vec![0.0f32; b * k];
        let mut valid = vec![0.0f32; b * k];
        let mut origins = vec![0.0f32; b * 2];

        for (bi, &t) in tiles.iter().enumerate() {
            origins[bi * 2] = (t % grid_x * TILE) as f32;
            origins[bi * 2 + 1] = (t / grid_x * TILE) as f32;
            for (ki, &sid) in bins.tile(t).iter().enumerate() {
                let s = &splats[sid as usize];
                let o = bi * k + ki;
                means[o * 2] = s.mean.x;
                means[o * 2 + 1] = s.mean.y;
                conics[o * 3] = s.conic.0;
                conics[o * 3 + 1] = s.conic.1;
                conics[o * 3 + 2] = s.conic.2;
                colors[o * 3] = s.color.x;
                colors[o * 3 + 1] = s.color.y;
                colors[o * 3 + 2] = s.color.z;
                opac[o] = s.opacity;
                depths[o] = s.depth;
                valid[o] = 1.0;
            }
        }
        let bg = [background.x, background.y, background.z];

        let exe = self.executable(&name)?;
        let inputs = [
            xla::Literal::vec1(&means).reshape(&[b as i64, k as i64, 2])?,
            xla::Literal::vec1(&conics).reshape(&[b as i64, k as i64, 3])?,
            xla::Literal::vec1(&colors).reshape(&[b as i64, k as i64, 3])?,
            xla::Literal::vec1(&opac).reshape(&[b as i64, k as i64])?,
            xla::Literal::vec1(&depths).reshape(&[b as i64, k as i64])?,
            xla::Literal::vec1(&valid).reshape(&[b as i64, k as i64])?,
            xla::Literal::vec1(&origins).reshape(&[b as i64, 2])?,
            xla::Literal::vec1(&bg).reshape(&[3])?,
        ];
        let result = exe.execute::<xla::Literal>(&inputs)?[0][0].to_literal_sync()?;
        let (rgb_l, alpha_l, depth_l, trunc_l) = result.to_tuple4()?;
        let rgb = rgb_l.to_vec::<f32>()?;
        let alpha = alpha_l.to_vec::<f32>()?;
        let depth = depth_l.to_vec::<f32>()?;
        let trunc = trunc_l.to_vec::<f32>()?;

        for (bi, &t) in tiles.iter().enumerate() {
            let (x0, y0, x1, y1) = frame.tile_bounds(t);
            for py in 0..(y1 - y0) {
                for px in 0..(x1 - x0) {
                    let src = bi * TILE * TILE + py * TILE + px;
                    let gi = frame.idx(x0 + px, y0 + py);
                    frame.rgb[gi * 3] = rgb[src * 3];
                    frame.rgb[gi * 3 + 1] = rgb[src * 3 + 1];
                    frame.rgb[gi * 3 + 2] = rgb[src * 3 + 2];
                    frame.alpha[gi] = alpha[src];
                    frame.depth[gi] = sanitize(depth[src]);
                    frame.trunc_depth[gi] = sanitize(trunc[src]);
                    frame.valid[gi] = alpha[src] >= VALID_ALPHA;
                }
            }
        }
        Ok(())
    }
}

fn sanitize(v: f32) -> f32 {
    if v.is_finite() {
        v
    } else {
        INVALID_DEPTH
    }
}
