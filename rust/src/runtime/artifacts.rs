//! Artifact registry: locates `artifacts/`, parses `manifest.json` (written
//! by `python/compile/aot.py`) and resolves the right AOT variant for a
//! request (e.g. the smallest rasterize batch whose K fits a tile list).

use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

/// One AOT-lowered graph.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub path: PathBuf,
    pub kind: String,
    /// rasterize: tile batch size B.
    pub batch: usize,
    /// rasterize: padded Gaussian list length K.
    pub k: usize,
    /// project: chunk size N.
    pub chunk: usize,
    /// warp: frame dims.
    pub width: usize,
    pub height: usize,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct ArtifactManifest {
    pub dir: PathBuf,
    pub entries: Vec<ArtifactEntry>,
}

/// Search for the artifacts directory: explicit arg, `$LSG_ARTIFACTS`, or
/// `artifacts/` walking up from the current dir (so tests work from the
/// crate root and examples from anywhere inside the repo).
pub fn find_artifacts_dir(explicit: Option<&Path>) -> Result<PathBuf> {
    if let Some(p) = explicit {
        return Ok(p.to_path_buf());
    }
    if let Ok(env) = std::env::var("LSG_ARTIFACTS") {
        return Ok(PathBuf::from(env));
    }
    let mut dir = std::env::current_dir()?;
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() {
            return Ok(cand);
        }
        if !dir.pop() {
            bail!(
                "artifacts/manifest.json not found; run `make artifacts` \
                 (or set LSG_ARTIFACTS)"
            );
        }
    }
}

impl ArtifactManifest {
    /// Load and validate the manifest.
    pub fn load(dir: &Path) -> Result<ArtifactManifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;
        let arts = json
            .get("artifacts")
            .context("manifest missing 'artifacts'")?;
        let obj = match arts {
            Json::Obj(m) => m,
            _ => bail!("manifest 'artifacts' is not an object"),
        };
        let mut entries = Vec::new();
        for (name, e) in obj {
            let file = e.str_or("file", "");
            if file.is_empty() {
                bail!("artifact {name} missing 'file'");
            }
            let path = dir.join(file);
            if !path.exists() {
                bail!("artifact file {path:?} missing — re-run `make artifacts`");
            }
            entries.push(ArtifactEntry {
                name: name.clone(),
                path,
                kind: e.str_or("kind", "").to_string(),
                batch: e.f64_or("batch", 0.0) as usize,
                k: e.f64_or("k", 0.0) as usize,
                chunk: e.f64_or("chunk", 0.0) as usize,
                width: e.f64_or("width", 0.0) as usize,
                height: e.f64_or("height", 0.0) as usize,
            });
        }
        if entries.is_empty() {
            bail!("manifest has no artifacts");
        }
        Ok(ArtifactManifest {
            dir: dir.to_path_buf(),
            entries,
        })
    }

    /// Rasterize variants sorted by K ascending.
    pub fn rasterize_variants(&self) -> Vec<&ArtifactEntry> {
        let mut v: Vec<&ArtifactEntry> =
            self.entries.iter().filter(|e| e.kind == "rasterize").collect();
        v.sort_by_key(|e| e.k);
        v
    }

    /// Smallest rasterize variant with k >= needed.
    pub fn rasterize_for(&self, needed_k: usize) -> Option<&ArtifactEntry> {
        self.rasterize_variants()
            .into_iter()
            .find(|e| e.k >= needed_k)
    }

    pub fn by_name(&self, name: &str) -> Option<&ArtifactEntry> {
        self.entries.iter().find(|e| e.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_artifacts() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn loads_repo_manifest_when_present() {
        let Some(dir) = repo_artifacts() else {
            eprintln!("skipping: run `make artifacts` first");
            return;
        };
        let m = ArtifactManifest::load(&dir).unwrap();
        assert!(!m.rasterize_variants().is_empty());
        // Variant selection: smallest fitting K.
        let ks: Vec<usize> = m.rasterize_variants().iter().map(|e| e.k).collect();
        assert!(ks.windows(2).all(|w| w[0] < w[1]));
        let pick = m.rasterize_for(ks[0] + 1).unwrap();
        assert!(pick.k >= ks[0] + 1);
        assert!(m.rasterize_for(usize::MAX - 1).is_none());
    }

    #[test]
    fn rejects_missing_dir() {
        let res = ArtifactManifest::load(Path::new("/nonexistent/xyz"));
        assert!(res.is_err());
    }

    #[test]
    fn rejects_bad_manifest() {
        let dir = std::env::temp_dir().join("lsg_bad_manifest");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), "{\"artifacts\": {}}").unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
        std::fs::write(dir.join("manifest.json"), "not json").unwrap();
        assert!(ArtifactManifest::load(&dir).is_err());
    }
}
