//! Per-stage timing: scoped timers and an accumulating breakdown used by
//! the pipeline to report preprocessing / sorting / rasterization splits
//! (paper Fig. 3) and by the bench harness for the speedup tables.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates wall-clock time per named stage.
#[derive(Default, Debug, Clone)]
pub struct StageTimes {
    totals: BTreeMap<&'static str, Duration>,
    counts: BTreeMap<&'static str, u64>,
}

impl StageTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage`.
    pub fn time<T>(&mut self, stage: &'static str, f: impl FnOnce() -> T) -> T {
        let start = Instant::now();
        let out = f();
        self.add(stage, start.elapsed());
        out
    }

    pub fn add(&mut self, stage: &'static str, d: Duration) {
        *self.totals.entry(stage).or_default() += d;
        *self.counts.entry(stage).or_default() += 1;
    }

    pub fn merge(&mut self, other: &StageTimes) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_default() += *v;
        }
        for (k, c) in &other.counts {
            *self.counts.entry(k).or_default() += *c;
        }
    }

    pub fn total(&self, stage: &str) -> Duration {
        self.totals.get(stage).copied().unwrap_or_default()
    }

    pub fn seconds(&self, stage: &str) -> f64 {
        self.total(stage).as_secs_f64()
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.values().sum()
    }

    pub fn stages(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.totals.iter().map(|(k, v)| (*k, *v))
    }

    /// Render a one-line breakdown like `preprocess 12.1ms (18%) | sort ...`.
    pub fn breakdown(&self) -> String {
        let total = self.grand_total().as_secs_f64().max(1e-12);
        self.totals
            .iter()
            .map(|(k, v)| {
                format!(
                    "{k} {:.2}ms ({:.0}%)",
                    v.as_secs_f64() * 1e3,
                    v.as_secs_f64() / total * 100.0
                )
            })
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

/// Measure the best-of-n wall time of a closure (bench helper).
pub fn best_of<T>(n: usize, mut f: impl FnMut() -> T) -> (Duration, T) {
    assert!(n > 0);
    let mut best = Duration::MAX;
    let mut out = None;
    for _ in 0..n {
        let start = Instant::now();
        let v = f();
        let el = start.elapsed();
        if el < best {
            best = el;
        }
        out = Some(v);
    }
    (best, out.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut t = StageTimes::new();
        t.add("sort", Duration::from_millis(5));
        t.add("sort", Duration::from_millis(7));
        t.add("raster", Duration::from_millis(3));
        assert_eq!(t.total("sort"), Duration::from_millis(12));
        assert_eq!(t.grand_total(), Duration::from_millis(15));
    }

    #[test]
    fn time_returns_value() {
        let mut t = StageTimes::new();
        let v = t.time("x", || 42);
        assert_eq!(v, 42);
        assert!(t.total("x") > Duration::ZERO);
    }

    #[test]
    fn merge_sums() {
        let mut a = StageTimes::new();
        a.add("s", Duration::from_millis(1));
        let mut b = StageTimes::new();
        b.add("s", Duration::from_millis(2));
        b.add("t", Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.total("s"), Duration::from_millis(3));
        assert_eq!(a.total("t"), Duration::from_millis(4));
    }

    #[test]
    fn breakdown_contains_stages() {
        let mut t = StageTimes::new();
        t.add("preprocess", Duration::from_millis(1));
        t.add("sort", Duration::from_millis(1));
        let s = t.breakdown();
        assert!(s.contains("preprocess") && s.contains("sort"));
    }

    #[test]
    fn best_of_returns_min() {
        let (d, v) = best_of(3, || 7u32);
        assert_eq!(v, 7);
        assert!(d < Duration::from_secs(1));
    }
}
