//! Per-stage timing: scoped timers and an accumulating breakdown used by
//! the pipeline to report preprocessing / sorting / rasterization splits
//! (paper Fig. 3) and by the bench harness for the speedup tables.
//!
//! Backed by the telemetry histogram primitive
//! ([`LocalHistogram`](crate::telemetry::LocalHistogram)): every `add`
//! records into a per-stage log-linear histogram, so the Fig. 3
//! breakdown reports counts and percentiles, not just totals — and
//! [`StageTimes::time`] opens a telemetry span, so stage splits and
//! `LSG_TRACE` tracing share one clock path.

use crate::telemetry::{HistSummary, LocalHistogram};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates wall-clock time per named stage.
#[derive(Default, Debug, Clone)]
pub struct StageTimes {
    totals: BTreeMap<&'static str, Duration>,
    hists: BTreeMap<&'static str, LocalHistogram>,
}

impl StageTimes {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `stage` (and a telemetry span of the same
    /// name when `LSG_TRACE` is set).
    pub fn time<T>(&mut self, stage: &'static str, f: impl FnOnce() -> T) -> T {
        let _span = crate::telemetry::span(stage);
        let start = Instant::now();
        let out = f();
        self.add(stage, start.elapsed());
        out
    }

    pub fn add(&mut self, stage: &'static str, d: Duration) {
        *self.totals.entry(stage).or_default() += d;
        self.hists.entry(stage).or_default().record_duration(d);
    }

    pub fn merge(&mut self, other: &StageTimes) {
        for (k, v) in &other.totals {
            *self.totals.entry(k).or_default() += *v;
        }
        for (k, h) in &other.hists {
            self.hists.entry(k).or_default().merge(h);
        }
    }

    pub fn total(&self, stage: &str) -> Duration {
        self.totals.get(stage).copied().unwrap_or_default()
    }

    pub fn seconds(&self, stage: &str) -> f64 {
        self.total(stage).as_secs_f64()
    }

    /// Observations recorded under `stage`.
    pub fn count(&self, stage: &str) -> u64 {
        self.hists.get(stage).map(LocalHistogram::count).unwrap_or(0)
    }

    /// Approximate per-observation percentile for `stage` (`q` in
    /// `[0, 1]`, ≤ 1/8 relative error from the log-linear buckets).
    pub fn percentile(&self, stage: &str, q: f64) -> Duration {
        self.hists
            .get(stage)
            .map(|h| Duration::from_nanos(h.percentile(q)))
            .unwrap_or_default()
    }

    /// Full digest (count / mean / p50 / p95 / p99 / max, nanoseconds)
    /// for `stage`, if it was ever recorded.
    pub fn summary(&self, stage: &str) -> Option<HistSummary> {
        self.hists.get(stage).map(LocalHistogram::summary)
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.values().sum()
    }

    pub fn stages(&self) -> impl Iterator<Item = (&'static str, Duration)> + '_ {
        self.totals.iter().map(|(k, v)| (*k, *v))
    }

    /// Render a one-line breakdown like
    /// `preprocess 12.1ms (18%, n=10, p50 1.1ms, p95 2.3ms) | sort ...`.
    pub fn breakdown(&self) -> String {
        let total = self.grand_total().as_secs_f64().max(1e-12);
        self.totals
            .iter()
            .map(|(k, v)| {
                let (n, p50, p95) = self
                    .hists
                    .get(k)
                    .map(|h| {
                        (
                            h.count(),
                            h.percentile(0.50) as f64 / 1e6,
                            h.percentile(0.95) as f64 / 1e6,
                        )
                    })
                    .unwrap_or((0, 0.0, 0.0));
                format!(
                    "{k} {:.2}ms ({:.0}%, n={n}, p50 {p50:.2}ms, p95 {p95:.2}ms)",
                    v.as_secs_f64() * 1e3,
                    v.as_secs_f64() / total * 100.0
                )
            })
            .collect::<Vec<_>>()
            .join(" | ")
    }
}

/// Measure the best-of-n wall time of a closure (bench helper).
pub fn best_of<T>(n: usize, mut f: impl FnMut() -> T) -> (Duration, T) {
    assert!(n > 0);
    let mut best = Duration::MAX;
    let mut out = None;
    for _ in 0..n {
        let start = Instant::now();
        let v = f();
        let el = start.elapsed();
        if el < best {
            best = el;
        }
        out = Some(v);
    }
    (best, out.unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates() {
        let mut t = StageTimes::new();
        t.add("sort", Duration::from_millis(5));
        t.add("sort", Duration::from_millis(7));
        t.add("raster", Duration::from_millis(3));
        assert_eq!(t.total("sort"), Duration::from_millis(12));
        assert_eq!(t.grand_total(), Duration::from_millis(15));
        assert_eq!(t.count("sort"), 2);
        assert_eq!(t.count("raster"), 1);
        assert_eq!(t.count("absent"), 0);
    }

    #[test]
    fn time_returns_value() {
        let mut t = StageTimes::new();
        let v = t.time("x", || 42);
        assert_eq!(v, 42);
        assert!(t.total("x") > Duration::ZERO);
        assert_eq!(t.count("x"), 1);
    }

    #[test]
    fn merge_sums() {
        let mut a = StageTimes::new();
        a.add("s", Duration::from_millis(1));
        let mut b = StageTimes::new();
        b.add("s", Duration::from_millis(2));
        b.add("t", Duration::from_millis(4));
        a.merge(&b);
        assert_eq!(a.total("s"), Duration::from_millis(3));
        assert_eq!(a.total("t"), Duration::from_millis(4));
        assert_eq!(a.count("s"), 2);
    }

    #[test]
    fn breakdown_contains_stages() {
        let mut t = StageTimes::new();
        t.add("preprocess", Duration::from_millis(1));
        t.add("sort", Duration::from_millis(1));
        let s = t.breakdown();
        assert!(s.contains("preprocess") && s.contains("sort"));
        assert!(s.contains("n=1"), "breakdown lost counts: {s}");
        assert!(s.contains("p50"), "breakdown lost percentiles: {s}");
    }

    #[test]
    fn percentiles_track_observations() {
        let mut t = StageTimes::new();
        for ms in 1..=100u64 {
            t.add("stage", Duration::from_millis(ms));
        }
        let p50 = t.percentile("stage", 0.50).as_secs_f64() * 1e3;
        let p95 = t.percentile("stage", 0.95).as_secs_f64() * 1e3;
        assert!((p50 - 50.0).abs() / 50.0 <= 0.125, "p50 {p50}");
        assert!((p95 - 95.0).abs() / 95.0 <= 0.125, "p95 {p95}");
        let s = t.summary("stage").unwrap();
        assert_eq!(s.count, 100);
        assert!(t.summary("absent").is_none());
    }

    #[test]
    fn best_of_returns_min() {
        let (d, v) = best_of(3, || 7u32);
        assert_eq!(v, 7);
        assert!(d < Duration::from_secs(1));
    }
}
