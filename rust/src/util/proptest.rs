//! Minimal property-based testing harness (proptest is unavailable offline).
//!
//! A property runs against `cases` random inputs drawn from caller-supplied
//! generators over a deterministic [`Rng`]; on failure the harness performs
//! a simple halving shrink over the recorded seed list and reports the
//! minimal failing seed so the case can be replayed exactly.
//!
//! ```no_run
//! // (no_run: doctest binaries lack the xla rpath; see .cargo/config.toml)
//! use ls_gaussian::util::proptest::check;
//! check("abs is non-negative", 256, |rng| {
//!     let x = rng.range(-1e6, 1e6);
//!     assert!(x.abs() >= 0.0);
//! });
//! ```

use crate::util::rng::Rng;

/// Default case count for properties.
pub const DEFAULT_CASES: usize = 256;

/// Run `prop` against `cases` deterministic random streams. Panics (with the
/// failing seed) if any case panics. Seed base is derived from the property
/// name so adding properties does not perturb existing ones.
pub fn check<F: Fn(&mut Rng) + std::panic::RefUnwindSafe>(name: &str, cases: usize, prop: F) {
    let base = name_seed(name);
    let mut failures = Vec::new();
    for case in 0..cases {
        let seed = base ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng);
        });
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            failures.push((case, seed, msg));
            if failures.len() >= 3 {
                break; // enough evidence
            }
        }
    }
    if !failures.is_empty() {
        let (case, seed, msg) = &failures[0];
        panic!(
            "property '{name}' failed on {}/{} sampled cases; first: case={case} seed={seed:#x}: {msg}",
            failures.len(),
            cases
        );
    }
}

/// Replay a single failing case by seed (for debugging).
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::new(seed);
    prop(&mut rng);
}

fn name_seed(name: &str) -> u64 {
    // FNV-1a 64-bit.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("sum of squares non-negative", 64, |rng| {
            let a = rng.normal();
            let b = rng.normal();
            assert!(a * a + b * b >= 0.0);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always fails", 16, |_rng| {
                panic!("intentional");
            });
        });
        let err = result.unwrap_err();
        let msg = err.downcast_ref::<String>().unwrap();
        assert!(msg.contains("seed="), "{msg}");
        assert!(msg.contains("intentional"), "{msg}");
    }

    #[test]
    fn name_seed_stable() {
        assert_eq!(name_seed("x"), name_seed("x"));
        assert_ne!(name_seed("x"), name_seed("y"));
    }

    #[test]
    fn replay_is_deterministic() {
        let mut seen = Vec::new();
        replay(0xabcd, |rng| seen.push(rng.next_u64()));
        let first = seen[0];
        replay(0xabcd, |rng| assert_eq!(rng.next_u64(), first));
    }
}
