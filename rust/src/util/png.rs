//! Minimal PNG (8-bit RGB, zlib via flate2) and PPM writers for dumping
//! rendered frames. Only what the examples/benches need — no reading.

use std::io::Write;
use std::path::Path;

/// Write an 8-bit RGB PNG. `rgb` is row-major, 3 bytes/pixel.
pub fn write_png(path: &Path, width: usize, height: usize, rgb: &[u8]) -> std::io::Result<()> {
    assert_eq!(rgb.len(), width * height * 3, "rgb buffer size mismatch");
    let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
    file.write_all(b"\x89PNG\r\n\x1a\n")?;

    // IHDR
    let mut ihdr = Vec::with_capacity(13);
    ihdr.extend_from_slice(&(width as u32).to_be_bytes());
    ihdr.extend_from_slice(&(height as u32).to_be_bytes());
    ihdr.extend_from_slice(&[8, 2, 0, 0, 0]); // bit depth 8, color type 2 (RGB)
    write_chunk(&mut file, b"IHDR", &ihdr)?;

    // IDAT: filter byte 0 (None) per scanline, zlib-compressed.
    let mut raw = Vec::with_capacity(height * (1 + width * 3));
    for y in 0..height {
        raw.push(0u8);
        raw.extend_from_slice(&rgb[y * width * 3..(y + 1) * width * 3]);
    }
    let mut enc = flate2::write::ZlibEncoder::new(Vec::new(), flate2::Compression::fast());
    enc.write_all(&raw)?;
    let compressed = enc.finish()?;
    write_chunk(&mut file, b"IDAT", &compressed)?;
    write_chunk(&mut file, b"IEND", &[])?;
    Ok(())
}

fn write_chunk<W: Write>(w: &mut W, kind: &[u8; 4], data: &[u8]) -> std::io::Result<()> {
    w.write_all(&(data.len() as u32).to_be_bytes())?;
    w.write_all(kind)?;
    w.write_all(data)?;
    let mut hasher = crc32fast::Hasher::new();
    hasher.update(kind);
    hasher.update(data);
    w.write_all(&hasher.finalize().to_be_bytes())?;
    Ok(())
}

/// Write a binary PPM (P6) — trivially inspectable fallback format.
pub fn write_ppm(path: &Path, width: usize, height: usize, rgb: &[u8]) -> std::io::Result<()> {
    assert_eq!(rgb.len(), width * height * 3);
    let mut file = std::io::BufWriter::new(std::fs::File::create(path)?);
    write!(file, "P6\n{width} {height}\n255\n")?;
    file.write_all(rgb)
}

/// Convert an f32 RGB buffer in [0,1] to 8-bit sRGB-ish bytes (plain clamp
/// + scale; the paper's quality metrics operate in linear space anyway).
pub fn to_u8_rgb(rgb_f32: &[f32]) -> Vec<u8> {
    rgb_f32
        .iter()
        .map(|&v| (v.clamp(0.0, 1.0) * 255.0 + 0.5) as u8)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn png_has_signature_and_iend() {
        let dir = std::env::temp_dir().join("lsg_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.png");
        let rgb: Vec<u8> = (0..4 * 3 * 3).map(|i| (i * 7 % 256) as u8).collect();
        write_png(&p, 4, 3, &rgb).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert_eq!(&bytes[..8], b"\x89PNG\r\n\x1a\n");
        assert_eq!(&bytes[bytes.len() - 8..bytes.len() - 4], b"IEND");
    }

    #[test]
    fn ppm_roundtrip_header() {
        let dir = std::env::temp_dir().join("lsg_png_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.ppm");
        let rgb = vec![0u8; 2 * 2 * 3];
        write_ppm(&p, 2, 2, &rgb).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        assert!(bytes.starts_with(b"P6\n2 2\n255\n"));
        assert_eq!(bytes.len(), 11 + 12);
    }

    #[test]
    fn to_u8_clamps() {
        let v = to_u8_rgb(&[-0.5, 0.0, 0.5, 1.0, 2.0]);
        assert_eq!(v, vec![0, 0, 128, 255, 255]);
    }
}
