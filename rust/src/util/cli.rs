//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positionals and
//! subcommands. The binary in `main.rs` builds its command tree from this.

use std::collections::BTreeMap;

/// Parsed arguments: subcommand path, options, flags, positionals.
#[derive(Debug, Default, Clone)]
pub struct Args {
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse raw argv (without the program name).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let mut args = Args::default();
        let mut iter = argv.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(stripped.to_string(), v);
                } else {
                    args.flags.push(stripped.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }

    pub fn f32_or(&self, name: &str, default: f32) -> f32 {
        self.get(name)
            .and_then(|s| s.parse().ok())
            .unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn options_and_flags() {
        // NB: a bare `--name` followed by a non-dashed token binds as an
        // option (`--verbose out.png` would parse as verbose="out.png"),
        // so flags go last or use `--key=value` forms.
        let a = parse("render out.png --scene train --frames=10 --verbose");
        assert_eq!(a.positional, vec!["render", "out.png"]);
        assert_eq!(a.get("scene"), Some("train"));
        assert_eq!(a.usize_or("frames", 0), 10);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn flag_before_option() {
        let a = parse("--fast --n 5");
        assert!(a.flag("fast"));
        assert_eq!(a.usize_or("n", 0), 5);
    }

    #[test]
    fn defaults() {
        let a = parse("");
        assert_eq!(a.usize_or("n", 3), 3);
        assert_eq!(a.f32_or("x", 1.5), 1.5);
        assert_eq!(a.get_or("mode", "native"), "native");
    }

    #[test]
    fn negative_number_as_value() {
        // "--shift -3" : "-3" does not start with "--" so it is a value.
        let a = parse("--shift -3");
        assert_eq!(a.get("shift"), Some("-3"));
    }
}
