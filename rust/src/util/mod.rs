//! Infrastructure substrates: deterministic RNG, JSON, CLI parsing, thread
//! pool, property-test harness, image IO, timers.
//!
//! These exist because the build is fully offline: the usual crates
//! (`rand`, `serde_json`, `clap`, `rayon`, `criterion`, `proptest`,
//! `image`) are not in the vendored set, so the repo carries minimal,
//! well-tested replacements.

pub mod cli;
pub mod json;
pub mod png;
pub mod pool;
pub mod proptest;
pub mod rng;
pub mod timer;

pub use rng::Rng;
