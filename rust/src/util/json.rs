//! Minimal JSON value model, parser and writer.
//!
//! Used for run configs, bench reports and scene metadata. `serde`'s facade
//! crate is not available offline, so this carries a small recursive-descent
//! parser (strict enough for our own files, tolerant of whitespace) and a
//! pretty-printer.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Numbers are kept as f64 (sufficient for configs/reports).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val.into());
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Fetch a nested f64 or return `default`.
    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|j| j.as_f64()).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(|j| j.as_str()).unwrap_or(default)
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Compact single-line rendering.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<f32> for Json {
    fn from(x: f32) -> Json {
        Json::Num(x as f64)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Json {
        Json::Arr(v.into_iter().map(|x| x.into()).collect())
    }
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(val)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => self.string().map(Json::Str),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 5 > self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar.
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b"),
            Some(&Json::Null)
        );
        assert_eq!(v.get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn roundtrip_pretty() {
        let mut o = Json::obj();
        o.set("speedup", 5.41).set("scene", "drjohnson").set(
            "values",
            vec![1.0f64, 2.5, -3.0],
        );
        let text = o.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), o);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v, Json::Str("Aé".to_string()));
    }

    #[test]
    fn f64_or_defaults() {
        let v = Json::parse(r#"{"n": 3}"#).unwrap();
        assert_eq!(v.f64_or("n", 0.0), 3.0);
        assert_eq!(v.f64_or("missing", 7.0), 7.0);
    }
}
