//! Deterministic PRNG (xoshiro256**) used everywhere randomness is needed:
//! scene generation, trajectories, property tests, workload jitter.
//!
//! Determinism is a design requirement (DESIGN.md §Key design decisions):
//! every bench prints its seed and reruns bit-identically.

/// xoshiro256** by Blackman & Vigna — small, fast, high-quality.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via splitmix64 so that nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        // 24 mantissa bits.
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1) with f64 precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform integer in [0, n). n must be > 0.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.f32();
            if u1 > 1e-12 {
                let u2 = self.f32();
                let r = (-2.0 * u1.ln()).sqrt();
                return r * (2.0 * std::f32::consts::PI * u2).cos();
            }
        }
    }

    /// Normal with the given mean and standard deviation.
    #[inline]
    pub fn normal_with(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal()
    }

    /// Log-normal: exp(N(mu, sigma)). Heavy-tailed — used for Gaussian
    /// scales and per-tile workload skew (the paper's Fig. 5 shows
    /// >10x spread in per-tile counts, a log-normal-like distribution).
    #[inline]
    pub fn log_normal(&mut self, mu: f32, sigma: f32) -> f32 {
        self.normal_with(mu, sigma).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Fork an independent stream (for per-thread determinism).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x), "{x}");
        }
    }

    #[test]
    fn f32_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f32() as f64).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_independent() {
        let mut base = Rng::new(9);
        let mut f1 = base.fork();
        let mut f2 = base.fork();
        let same = (0..64).filter(|_| f1.next_u64() == f2.next_u64()).count();
        assert_eq!(same, 0);
    }
}
