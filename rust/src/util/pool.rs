//! Scoped thread pool + parallel-for (rayon/tokio are unavailable offline).
//!
//! The coordinator's rasterization blocks and the bench harness use
//! [`parallel_for`] for data parallelism and [`WorkerPool`] for the
//! streaming pipeline's long-lived stage workers.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Number of worker threads to use by default (physical parallelism).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(i)` for every i in 0..n using `threads` OS threads with dynamic
/// (chunk-stealing) scheduling. `f` must be Sync; per-item outputs should go
/// through interior mutability or be written to disjoint slice regions by
/// the caller (see [`parallel_map`]).
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // Chunk to amortize the atomic; small enough to balance skewed loads.
    let chunk = (n / (threads * 8)).max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Parallel map preserving order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(
    n: usize,
    threads: usize,
    f: F,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = out.as_mut_ptr() as usize;
        parallel_for(n, threads, |i| {
            // SAFETY: each index i is visited exactly once, so the writes
            // target disjoint slots; the Vec outlives the scoped threads.
            unsafe {
                let p = (slots as *mut Option<T>).add(i);
                std::ptr::write(p, Some(f(i)));
            }
        });
    }
    out.into_iter().map(|x| x.unwrap()).collect()
}

/// A long-lived pool of workers consuming boxed jobs; used by the streaming
/// coordinator for pipeline stages.
pub struct WorkerPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    pending: Arc<(Mutex<usize>, std::sync::Condvar)>,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let pending = Arc::new((Mutex::new(0usize), std::sync::Condvar::new()));
        let mut handles = Vec::new();
        for _ in 0..threads {
            let rx = Arc::clone(&rx);
            let pending = Arc::clone(&pending);
            handles.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = rx.lock().unwrap();
                    guard.recv()
                };
                match job {
                    Ok(job) => {
                        job();
                        let (lock, cv) = &*pending;
                        let mut p = lock.lock().unwrap();
                        *p -= 1;
                        cv.notify_all();
                    }
                    Err(_) => break,
                }
            }));
        }
        WorkerPool {
            tx: Some(tx),
            handles,
            pending,
        }
    }

    /// Submit a job.
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let (lock, _) = &*self.pending;
        *lock.lock().unwrap() += 1;
        self.tx
            .as_ref()
            .expect("pool shut down")
            .send(Box::new(f))
            .expect("worker died");
    }

    /// Block until every submitted job has completed.
    pub fn wait_idle(&self) {
        let (lock, cv) = &*self.pending;
        let mut p = lock.lock().unwrap();
        while *p > 0 {
            p = cv.wait(p).unwrap();
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let count = AtomicUsize::new(0);
        parallel_for(1, 4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_runs_jobs_and_waits() {
        let pool = WorkerPool::new(4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn pool_drop_joins() {
        let pool = WorkerPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }
}
