//! Long-lived worker pool + parallel-for (rayon/tokio are unavailable
//! offline).
//!
//! The streaming redesign (ISSUE 1) moved all tile-level parallelism off
//! per-call `std::thread::scope` spawns and onto a persistent
//! [`WorkerPool`]: [`WorkerPool::parallel_for`] dispatches a *gang task*
//! (a raw borrowed closure + an atomic work counter) to the already-parked
//! workers, so a steady-state frame performs **zero heap allocations and
//! zero thread spawns** for its rasterization fan-out. The pool also keeps
//! the original boxed-job queue ([`WorkerPool::submit`] /
//! [`WorkerPool::wait_idle`]) for coarse pipeline jobs.
//!
//! The free [`parallel_for`] (scoped spawn per call) remains for one-shot
//! callers that have no pool at hand.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Number of worker threads to use by default (physical parallelism).
pub fn default_threads() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Run `f(i)` for every i in 0..n using `threads` scoped OS threads with
/// dynamic (chunk-stealing) scheduling. Spawns threads per call — prefer
/// [`WorkerPool::parallel_for`] on hot paths.
pub fn parallel_for<F: Fn(usize) + Sync>(n: usize, threads: usize, f: F) {
    if n == 0 {
        return;
    }
    let threads = threads.max(1).min(n);
    if threads == 1 {
        for i in 0..n {
            f(i);
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // Chunk to amortize the atomic; small enough to balance skewed loads.
    let chunk = (n / (threads * 8)).max(1);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = next.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                let end = (start + chunk).min(n);
                for i in start..end {
                    f(i);
                }
            });
        }
    });
}

/// Parallel map preserving order.
pub fn parallel_map<T: Send, F: Fn(usize) -> T + Sync>(
    n: usize,
    threads: usize,
    f: F,
) -> Vec<T> {
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    {
        let slots = out.as_mut_ptr() as usize;
        parallel_for(n, threads, |i| {
            // SAFETY: each index i is visited exactly once, so the writes
            // target disjoint slots; the Vec outlives the scoped threads.
            unsafe {
                let p = (slots as *mut Option<T>).add(i);
                std::ptr::write(p, Some(f(i)));
            }
        });
    }
    out.into_iter().map(|x| x.unwrap()).collect()
}

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Maximum partitions of a planned dispatch
/// ([`WorkerPool::parallel_for_plan`]): the per-partition cursors live on
/// the dispatching caller's stack, keeping the steady state
/// allocation-free. Planners cap their partition count to this
/// (`render::dispatch::MAX_PLAN_WORKERS` aliases it).
pub const MAX_PLAN_PARTS: usize = 64;

/// Shared state of one planned dispatch, owned by the dispatching
/// caller's stack frame (see [`WorkerPool::parallel_for_plan`]).
struct PlanShared {
    /// Permutation of 0..n: the execution order.
    order: *const u32,
    /// Partition offsets into `order`, len `n_parts + 1`.
    parts: *const u32,
    n_parts: usize,
    /// Per-partition progress cursors (offset within the partition).
    cursors: *const AtomicUsize,
    /// Next unclaimed partition.
    claim: *const AtomicUsize,
    /// Indices executed by a non-owner (the steal fallback).
    steals: *const AtomicUsize,
}

/// A borrowed data-parallel task published to the workers: an erased
/// closure pointer plus a shared work counter — or, for planned
/// dispatches, a pointer to the caller's [`PlanShared`]. Lives only for
/// the duration of one [`WorkerPool::parallel_for`] /
/// [`WorkerPool::parallel_for_plan`] call (the caller blocks until every
/// joined worker has left the task before the borrow ends).
#[derive(Clone, Copy)]
struct Gang {
    /// Type-erased `&F` where `F: Fn(usize) + Sync`.
    data: *const (),
    /// Monomorphized trampoline re-typing `data` and calling it.
    call: unsafe fn(*const (), usize),
    /// Shared index counter (points into the caller's stack frame).
    next: *const AtomicUsize,
    n: usize,
    chunk: usize,
    /// Planned dispatch state; null for index-order gangs.
    plan: *const PlanShared,
}
// SAFETY: the pointers target `Sync` data owned by the dispatching caller,
// which outlives every worker's use of them (see `parallel_for`'s
// completion wait).
unsafe impl Send for Gang {}

unsafe fn gang_call<F: Fn(usize) + Sync>(data: *const (), i: usize) {
    (*(data as *const F))(i)
}

/// Drain one gang task as a participant (worker or dispatching caller):
/// index-order chunk stealing, or the plan's claim-own-partitions-then-
/// steal protocol.
///
/// SAFETY: caller must guarantee the gang's pointers are alive — the
/// dispatching caller keeps them so until `gang_active` returns to 0.
unsafe fn drain_gang(g: &Gang) {
    if g.plan.is_null() {
        let next = &*g.next;
        loop {
            let start = next.fetch_add(g.chunk, Ordering::Relaxed);
            if start >= g.n {
                break;
            }
            let end = (start + g.chunk).min(g.n);
            for i in start..end {
                (g.call)(g.data, i);
            }
        }
    } else {
        drain_plan(&*g.plan, g);
    }
}

/// Plan execution: claim whole partitions while any remain (heavy-first
/// order inside each), then steal leftovers from other partitions one
/// index at a time. Every index runs exactly once (each cursor value is
/// handed out by exactly one `fetch_add`).
unsafe fn drain_plan(p: &PlanShared, g: &Gang) {
    let order = std::slice::from_raw_parts(p.order, g.n);
    let parts = std::slice::from_raw_parts(p.parts, p.n_parts + 1);
    let cursors = std::slice::from_raw_parts(p.cursors, p.n_parts);
    let drain_partition = |k: usize| -> usize {
        let (lo, hi) = (parts[k] as usize, parts[k + 1] as usize);
        let len = hi - lo;
        let mut ran = 0usize;
        loop {
            let c = cursors[k].fetch_add(1, Ordering::Relaxed);
            if c >= len {
                break;
            }
            // SAFETY: same contract as the enclosing fn — the caller
            // keeps the closure alive until every participant leaves.
            unsafe { (g.call)(g.data, order[lo + c] as usize) };
            ran += 1;
        }
        ran
    };
    // Own phase: claim and drain whole partitions.
    loop {
        let k = (*p.claim).fetch_add(1, Ordering::Relaxed);
        if k >= p.n_parts {
            break;
        }
        drain_partition(k);
    }
    // Steal phase: sweep the other partitions until nothing is left.
    let mut stolen = 0usize;
    loop {
        let mut any = false;
        for k in 0..p.n_parts {
            let ran = drain_partition(k);
            stolen += ran;
            any |= ran > 0;
        }
        if !any {
            break;
        }
    }
    if stolen > 0 {
        (*p.steals).fetch_add(stolen, Ordering::Relaxed);
    }
}

struct State {
    jobs: VecDeque<Job>,
    /// Queued + currently running boxed jobs.
    jobs_pending: usize,
    gang: Option<Gang>,
    /// Bumped per gang so a worker never re-joins a task it already left.
    gang_epoch: u64,
    /// Workers currently executing the gang task.
    gang_active: usize,
    /// Remaining worker slots for the current gang (caps parallelism).
    gang_slots: usize,
    shutdown: bool,
}

struct Inner {
    state: Mutex<State>,
    /// Workers park here waiting for jobs or gang tasks.
    work_cv: Condvar,
    /// Callers park here waiting for gang completion / queue idle / a free
    /// gang slot.
    done_cv: Condvar,
}

/// A persistent pool of parked worker threads. One pool serves both the
/// tile-parallel render fan-out (`parallel_for`, allocation-free) and
/// coarse boxed jobs (`submit` + `wait_idle`). Shared across all
/// `StreamSession`s of a `StreamServer`.
pub struct WorkerPool {
    inner: Arc<Inner>,
    handles: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let inner = Arc::new(Inner {
            state: Mutex::new(State {
                jobs: VecDeque::new(),
                jobs_pending: 0,
                gang: None,
                gang_epoch: 0,
                gang_active: 0,
                gang_slots: 0,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut handles = Vec::new();
        for _ in 0..threads {
            let inner = Arc::clone(&inner);
            handles.push(std::thread::spawn(move || worker_loop(&inner)));
        }
        WorkerPool {
            inner,
            handles,
            threads,
        }
    }

    /// Number of worker threads.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Boxed jobs queued or currently running (the coarse-job load).
    pub fn pending_jobs(&self) -> usize {
        self.inner.state.lock().unwrap().jobs_pending
    }

    /// Idle-capacity hint: worker slots not occupied by boxed jobs or a
    /// gang task *right now*. Advisory only (the answer can be stale by
    /// the time the caller acts on it) — used by the session scheduler to
    /// decide whether spare capacity exists for opportunistic work such
    /// as predictive shard prefetch.
    pub fn idle_capacity(&self) -> usize {
        let st = self.inner.state.lock().unwrap();
        self.threads
            .saturating_sub(st.jobs_pending + st.gang_active)
    }

    /// Submit a boxed job (allocates; for coarse pipeline work).
    pub fn submit<F: FnOnce() + Send + 'static>(&self, f: F) {
        let mut st = self.inner.state.lock().unwrap();
        assert!(!st.shutdown, "pool shut down");
        st.jobs.push_back(Box::new(f));
        st.jobs_pending += 1;
        drop(st);
        self.inner.work_cv.notify_one();
    }

    /// Block until every submitted boxed job has completed.
    pub fn wait_idle(&self) {
        let mut st = self.inner.state.lock().unwrap();
        while st.jobs_pending > 0 {
            st = self.inner.done_cv.wait(st).unwrap();
        }
    }

    /// Run `f(i)` for every i in 0..n across the parked workers with
    /// dynamic chunk-stealing, using at most `max_threads` threads in
    /// total (the calling thread participates and guarantees progress even
    /// when every worker is busy elsewhere). Allocation-free: the closure
    /// is borrowed, not boxed. If another caller's gang currently occupies
    /// the workers, the call falls back to inline execution instead of
    /// sleeping — concurrent sessions never serialize on the pool.
    pub fn parallel_for<F: Fn(usize) + Sync>(&self, n: usize, max_threads: usize, f: F) {
        if n == 0 {
            return;
        }
        let total = max_threads.max(1).min(n);
        if total == 1 {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let worker_slots = (total - 1).min(self.threads);
        let next = AtomicUsize::new(0);
        let chunk = (n / (total * 8)).max(1);
        let gang = Gang {
            data: &f as *const F as *const (),
            call: gang_call::<F>,
            next: &next as *const AtomicUsize,
            n,
            chunk,
            plan: std::ptr::null(),
        };
        if !self.publish_gang(gang, worker_slots) {
            // Workers are busy with another caller's gang: run inline
            // rather than sleeping for the slot (the caller is the
            // progress guarantee either way).
            for i in 0..n {
                f(i);
            }
            return;
        }
        // From here on, `f` and `next` are published to the workers: the
        // guard guarantees — even if `f` panics below — that we wait for
        // every joined worker to leave and clear the slot before this
        // stack frame (and the borrows in `gang`) dies.
        let _guard = GangGuard(&self.inner);
        // The caller drains the counter too: progress never depends on a
        // worker being free.
        unsafe { drain_gang(&gang) };
    }

    /// Execute a caller-provided dispatch plan across the parked workers:
    /// `order` is a permutation of `0..n` (the execution order, e.g.
    /// heavy-first) and `parts` its partition offsets (len = partitions +
    /// 1, as built by [`crate::render::dispatch::plan_into`]). Each
    /// participant — the calling thread always included — claims whole
    /// partitions first, then falls back to **stealing** leftover indices
    /// from other partitions one at a time, so a mispredicted partition
    /// never serializes the frame tail. Returns the number of stolen
    /// (non-owner-executed) indices.
    ///
    /// Allocation-free: the closure is borrowed and the plan's shared
    /// cursors live on this call's stack (hence the
    /// [`MAX_PLAN_PARTS`] cap). Like [`WorkerPool::parallel_for`], falls
    /// back to inline execution (in plan order, zero steals) when another
    /// caller's gang occupies the workers.
    pub fn parallel_for_plan<F: Fn(usize) + Sync>(
        &self,
        order: &[u32],
        parts: &[u32],
        f: F,
    ) -> u32 {
        let n = order.len();
        if n == 0 {
            return 0;
        }
        let n_parts = parts.len().saturating_sub(1);
        assert!(n_parts <= MAX_PLAN_PARTS, "plan exceeds MAX_PLAN_PARTS");
        debug_assert_eq!(parts.first().copied(), Some(0));
        debug_assert_eq!(parts.last().copied(), Some(n as u32));
        let run_inline = |f: &F| {
            for &t in order {
                f(t as usize);
            }
        };
        if n_parts <= 1 {
            run_inline(&f);
            return 0;
        }
        let cursors: [AtomicUsize; MAX_PLAN_PARTS] = std::array::from_fn(|_| AtomicUsize::new(0));
        let claim = AtomicUsize::new(0);
        let steals = AtomicUsize::new(0);
        let plan = PlanShared {
            order: order.as_ptr(),
            parts: parts.as_ptr(),
            n_parts,
            cursors: cursors.as_ptr(),
            claim: &claim as *const AtomicUsize,
            steals: &steals as *const AtomicUsize,
        };
        let gang = Gang {
            data: &f as *const F as *const (),
            call: gang_call::<F>,
            next: std::ptr::null(),
            n,
            chunk: 1,
            plan: &plan as *const PlanShared,
        };
        let worker_slots = (n_parts - 1).min(self.threads);
        if !self.publish_gang(gang, worker_slots) {
            run_inline(&f);
            return 0;
        }
        // Everything `gang` points at (f, plan, cursors, claim, steals)
        // is declared before the guard, so the guard's drop — which waits
        // out every joined worker — runs first on unwind too.
        let guard = GangGuard(&self.inner);
        unsafe { drain_gang(&gang) };
        // Wait out every joined worker BEFORE reading the steal counter
        // (workers may still be finishing their last stolen tile).
        drop(guard);
        steals.load(Ordering::Relaxed) as u32
    }

    /// Publish a gang to the parked workers; false when another caller's
    /// gang currently occupies them (the caller should run inline).
    fn publish_gang(&self, gang: Gang, worker_slots: usize) -> bool {
        let mut st = self.inner.state.lock().unwrap();
        if st.gang.is_some() {
            return false;
        }
        st.gang = Some(gang);
        st.gang_epoch += 1;
        st.gang_slots = worker_slots;
        drop(st);
        self.inner.work_cv.notify_all();
        true
    }
}

/// Completion guard for a published gang: waits out every joined worker
/// and frees the slot, on both the normal path and caller unwind (a panic
/// in the task must not leave workers holding dangling pointers, nor wedge
/// the pool).
struct GangGuard<'a>(&'a Inner);

impl Drop for GangGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        while st.gang_active > 0 {
            st = self.0.done_cv.wait(st).unwrap();
        }
        st.gang = None;
        st.gang_slots = 0;
        drop(st);
        self.0.done_cv.notify_all();
    }
}

/// Worker-side guard: the active count must drop even if the gang task
/// panics on this worker (the thread dies, but the dispatching caller must
/// not hang waiting for it).
struct ActiveGuard<'a>(&'a Inner);

impl Drop for ActiveGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.0.state.lock().unwrap();
        st.gang_active -= 1;
        if st.gang_active == 0 {
            self.0.done_cv.notify_all();
        }
    }
}

enum Work {
    Job(Job),
    Gang(Gang, u64),
}

fn worker_loop(inner: &Inner) {
    let mut last_epoch = 0u64;
    loop {
        let work = {
            let mut st = inner.state.lock().unwrap();
            loop {
                // Drain queued jobs even during shutdown (drop joins after
                // running what was submitted, as the seed pool did).
                if let Some(job) = st.jobs.pop_front() {
                    break Work::Job(job);
                }
                if st.shutdown {
                    return;
                }
                if let Some(g) = st.gang {
                    if st.gang_epoch != last_epoch && st.gang_slots > 0 {
                        st.gang_slots -= 1;
                        st.gang_active += 1;
                        break Work::Gang(g, st.gang_epoch);
                    }
                }
                st = inner.work_cv.wait(st).unwrap();
            }
        };
        match work {
            Work::Job(job) => {
                job();
                let mut st = inner.state.lock().unwrap();
                st.jobs_pending -= 1;
                if st.jobs_pending == 0 {
                    inner.done_cv.notify_all();
                }
            }
            Work::Gang(g, epoch) => {
                last_epoch = epoch;
                // Decrements gang_active even if the task panics below.
                let _active = ActiveGuard(inner);
                // SAFETY: the dispatching caller keeps the closure, the
                // counter and any plan state alive until `gang_active`
                // returns to 0, which it observes under the same lock
                // that guarded our join.
                unsafe { drain_gang(&g) };
            }
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.inner.state.lock().unwrap();
            st.shutdown = true;
        }
        self.inner.work_cv.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn parallel_for_visits_all_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_for(1000, 8, |i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn parallel_map_order() {
        let v = parallel_map(100, 4, |i| i * i);
        assert_eq!(v, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_for_zero_and_one() {
        parallel_for(0, 4, |_| panic!("should not run"));
        let count = AtomicUsize::new(0);
        parallel_for(1, 4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn pool_runs_jobs_and_waits() {
        let pool = WorkerPool::new(4);
        let sum = Arc::new(AtomicU64::new(0));
        for i in 0..100u64 {
            let sum = Arc::clone(&sum);
            pool.submit(move || {
                sum.fetch_add(i, Ordering::Relaxed);
            });
        }
        pool.wait_idle();
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn pool_drop_joins() {
        let pool = WorkerPool::new(2);
        pool.submit(|| std::thread::sleep(std::time::Duration::from_millis(10)));
        drop(pool); // must not hang or panic
    }

    #[test]
    fn pool_parallel_for_visits_all_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> = (0..2000).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..5 {
            // repeated dispatches reuse the same parked workers
            pool.parallel_for(2000, 8, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 5));
    }

    #[test]
    fn pool_parallel_for_single_thread_is_inline() {
        let pool = WorkerPool::new(4);
        let count = AtomicUsize::new(0);
        pool.parallel_for(100, 1, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 100);
    }

    #[test]
    fn pool_parallel_for_concurrent_callers() {
        // Two threads dispatching gangs on one pool must both complete
        // (the caller always participates, so no deadlock).
        let pool = Arc::new(WorkerPool::new(2));
        let total = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                s.spawn(move || {
                    for _ in 0..20 {
                        pool.parallel_for(64, 4, |i| {
                            total.fetch_add(i as u64, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * (63 * 64 / 2) as u64);
    }

    #[test]
    fn idle_capacity_tracks_boxed_jobs() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.idle_capacity(), 2);
        assert_eq!(pool.pending_jobs(), 0);
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        for _ in 0..2 {
            let gate = Arc::clone(&gate);
            pool.submit(move || {
                let (lock, cv) = &*gate;
                let mut open = lock.lock().unwrap();
                while !*open {
                    open = cv.wait(open).unwrap();
                }
            });
        }
        // Both workers are parked in jobs: no idle capacity.
        while pool.idle_capacity() > 0 {
            std::thread::yield_now();
        }
        assert_eq!(pool.idle_capacity(), 0);
        {
            let (lock, cv) = &*gate;
            *lock.lock().unwrap() = true;
            cv.notify_all();
        }
        pool.wait_idle();
        assert_eq!(pool.idle_capacity(), 2);
    }

    /// Equal-count 4-partition plan over 0..n in identity order.
    fn identity_plan(n: usize, parts_n: usize) -> (Vec<u32>, Vec<u32>) {
        let order: Vec<u32> = (0..n as u32).collect();
        let per = n.div_ceil(parts_n);
        let parts: Vec<u32> = (0..=parts_n).map(|k| ((k * per).min(n)) as u32).collect();
        (order, parts)
    }

    #[test]
    fn plan_dispatch_visits_all_once() {
        let pool = WorkerPool::new(4);
        let (order, parts) = identity_plan(777, 4);
        let hits: Vec<AtomicUsize> = (0..777).map(|_| AtomicUsize::new(0)).collect();
        for _ in 0..5 {
            pool.parallel_for_plan(&order, &parts, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
        }
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 5));
    }

    #[test]
    fn plan_dispatch_follows_permutation() {
        // A shuffled permutation with a single partition runs inline in
        // exactly the plan's order.
        let pool = WorkerPool::new(2);
        let order: Vec<u32> = (0..64u32).rev().collect();
        let parts = vec![0u32, 64];
        let log = Mutex::new(Vec::new());
        let steals = pool.parallel_for_plan(&order, &parts, |i| {
            log.lock().unwrap().push(i as u32);
        });
        assert_eq!(steals, 0);
        assert_eq!(*log.lock().unwrap(), order);
    }

    #[test]
    fn plan_dispatch_steals_imbalanced_tail() {
        // Partition 0 holds ALL the work, partitions 1..4 are empty: the
        // other participants must steal from it rather than idle.
        let pool = WorkerPool::new(4);
        let n = 2000usize;
        let order: Vec<u32> = (0..n as u32).collect();
        let parts = vec![0u32, n as u32, n as u32, n as u32, n as u32];
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        let mut total_steals = 0u32;
        for _ in 0..10 {
            total_steals += pool.parallel_for_plan(&order, &parts, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
                // Enough work per index that workers join before the
                // caller drains everything alone.
                std::hint::black_box((0..50).sum::<u64>());
            });
        }
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 10));
        assert!(total_steals > 0, "no steals across 10 imbalanced dispatches");
    }

    #[test]
    fn plan_dispatch_zero_and_empty_partitions() {
        let pool = WorkerPool::new(2);
        assert_eq!(pool.parallel_for_plan(&[], &[0], |_| panic!("no work")), 0);
        // Empty middle partitions are skipped.
        let order = vec![0u32, 1];
        let parts = vec![0u32, 1, 1, 2];
        let count = AtomicUsize::new(0);
        pool.parallel_for_plan(&order, &parts, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(count.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn plan_dispatch_concurrent_callers() {
        // Concurrent planned dispatches on one pool must all complete
        // (losers of the gang slot run inline).
        let pool = Arc::new(WorkerPool::new(2));
        let total = Arc::new(AtomicU64::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let pool = Arc::clone(&pool);
                let total = Arc::clone(&total);
                s.spawn(move || {
                    let (order, parts) = identity_plan(64, 4);
                    for _ in 0..20 {
                        pool.parallel_for_plan(&order, &parts, |i| {
                            total.fetch_add(i as u64, Ordering::Relaxed);
                        });
                    }
                });
            }
        });
        assert_eq!(total.load(Ordering::Relaxed), 4 * 20 * (63 * 64 / 2) as u64);
    }

    #[test]
    fn pool_mixes_jobs_and_gangs() {
        let pool = WorkerPool::new(3);
        let jobs = Arc::new(AtomicU64::new(0));
        for _ in 0..10 {
            let jobs = Arc::clone(&jobs);
            pool.submit(move || {
                jobs.fetch_add(1, Ordering::Relaxed);
            });
        }
        let count = AtomicUsize::new(0);
        pool.parallel_for(500, 4, |_| {
            count.fetch_add(1, Ordering::Relaxed);
        });
        pool.wait_idle();
        assert_eq!(count.load(Ordering::Relaxed), 500);
        assert_eq!(jobs.load(Ordering::Relaxed), 10);
    }
}
