//! Unified telemetry: metrics hub, percentile histograms, per-session
//! frame rings, and a Chrome-trace span tracer across the serving stack.
//!
//! Four pieces, one invariant — *recording never allocates or locks in
//! steady state* (enforced by `rust/tests/zero_alloc.rs`):
//!
//! * [`hist`] — fixed-bucket log-linear [`Histogram`]s (atomic) and
//!   [`LocalHistogram`]s (single-owner), the percentile primitive.
//! * [`hub`] — the process-wide [`MetricsHub`] of counters + histograms
//!   fed by session steps, scheduler commits, shard loads, and governor
//!   evictions.
//! * [`ring`] — per-session bounded [`FrameRing`]s of committed
//!   [`FrameRecord`]s with windowed queries.
//! * [`trace`] — `LSG_TRACE=<path>` scoped [`span`]s over the real
//!   pipeline stages, flushed as Perfetto-loadable JSON; one relaxed
//!   atomic load per span when disabled. Runtime-toggleable since PR 10
//!   ([`start_trace`]/[`stop_trace`], driven by `POST /trace/start|stop`).
//!
//! PR 10 adds the live introspection plane on top:
//!
//! * [`flight`] — a process-global black-box ring of recent frame
//!   summaries + discrete node events, dumped as JSON on demand, from a
//!   panic hook, or when an anomaly trigger fires.
//! * [`probe`] — online served-vs-dense-reference PSNR/SSIM scoring on
//!   idle pool capacity, attributed per QoS rung.
//! * [`admin`] — a std-only HTTP/1.1 admin endpoint (`LSG_ADMIN=addr`)
//!   serving `/metrics`, `/snapshot.json`, `/healthz`, `/readyz`,
//!   `/sessions`, `/flightrecord`, and the trace toggle.
//!
//! Read-side aggregation lives in [`expo`]:
//! [`StreamServer::telemetry_snapshot`](crate::serve::StreamServer::telemetry_snapshot)
//! assembles a [`TelemetrySnapshot`] with JSON and Prometheus writers.
//! Env knobs and the Perfetto how-to are documented in
//! `docs/OBSERVABILITY.md`.
//!
//! The hub and rings also feed the closed QoS loop
//! ([`serve::qos`](crate::serve::qos), PR 8): the controller senses
//! [`FrameRing::iter_recent`] each paced commit (allocation-free), and
//! its decisions flow back as `qos_*` hub counters, the
//! `qos_headroom_pm` histogram, and the `qos_level` stamped on
//! [`FrameRecord`] / [`SessionTelemetry`].
//!
//! # Example
//!
//! Digest the process-wide hub without a server:
//!
//! ```
//! use ls_gaussian::telemetry::{hub, NodeTelemetry};
//!
//! hub().record_frame(true, 2_000_000); // 2 ms dense frame
//! let node = NodeTelemetry::capture();
//! assert!(node.frames >= 1);
//! assert!(node.frame_ns.count >= 1);
//! ```

pub mod admin;
pub mod expo;
pub mod flight;
pub mod hist;
pub mod hub;
pub mod probe;
pub mod ring;
pub mod trace;

pub use admin::{AdminConfig, AdminServer, HealthReport, HealthThresholds};
pub use expo::{
    NodeTelemetry, SceneTelemetry, SessionTelemetry, TelemetrySnapshot, SIZE_CLASS_LABELS,
};
pub use hist::{HistSummary, Histogram, LocalHistogram};
pub use hub::{hub, MetricsHub, QUALITY_RUNGS};
pub use probe::{ProbeDigest, QualityProbe};
pub use ring::{FrameRecord, FrameRing, RingSummary, DEFAULT_RING_CAP};
pub use trace::{
    complete, complete_on, flush as flush_trace, span, start as start_trace, stop as stop_trace,
    Span, SCHED_TRACK_BASE,
};
