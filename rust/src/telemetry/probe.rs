//! Online quality probes: score what the node actually served.
//!
//! The QoS ladder (`serve/qos.rs`, PR 8) senses *lateness* and trades
//! quality for deadline headroom — but until this PR the quality side of
//! that trade was only priced offline in the bench. A [`QualityProbe`]
//! closes the gap at runtime: every Nth **warped** frame (configurable
//! `probe_interval` on [`CoordinatorConfig`](crate::coordinator::CoordinatorConfig),
//! default 0 = off), it copies the served RGB, then — on a worker-pool
//! job, off the session thread — renders the dense reference into a
//! dedicated probe scratch and scores PSNR + SSIM (the [`crate::metrics`]
//! implementations) of served vs reference. Scores feed the hub's
//! per-QoS-rung histograms
//! ([`MetricsHub::record_probe`](crate::telemetry::MetricsHub::record_probe)) so the
//! snapshot and both exposition writers can attribute visual quality to
//! the ladder rung that produced it.
//!
//! Design constraints, in order:
//!
//! * **Default off, bit-parity preserved.** With `probe_interval = 0`
//!   the session never constructs a probe; the step path pays one
//!   `Option` branch. The zero-alloc steady-state test runs the default
//!   config and is unaffected.
//! * **Never stall the serving path.** At most one probe is in flight
//!   (an atomic latch); a probe only launches when the pool reports
//!   idle capacity. Busy node ⇒ probes are *skipped* (counted in
//!   `probe_skipped`), never queued behind frame work.
//! * **Alloc-light.** The probe renderer, reference [`Frame`],
//!   [`FrameScratch`] and the served-RGB copy buffer are persistent;
//!   a firing probe allocates only the boxed pool job. Non-firing
//!   warped frames cost a counter increment.
//!
//! The dense reference is rendered through the same
//! [`Renderer::execute`] pipeline with the session's *base* config, so
//! the probe measures exactly the reference the warp approximates
//! (paper Sec. VI-B's PSNR-vs-dense methodology, moved online).

use crate::render::{Frame, FrameScratch, RenderPass, Renderer};
use crate::scene::Pose;
use crate::telemetry::hub;
use crate::util::pool::WorkerPool;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

/// PSNR is clamped here before scaling to centi-dB: identical frames
/// would otherwise score +inf.
const PSNR_CAP_DB: f64 = 99.0;

/// Per-session digest of every probe scored so far — the compact view
/// carried by [`SessionTelemetry`](crate::telemetry::SessionTelemetry)
/// and printed by `examples/edge_fleet.rs`.
#[derive(Clone, Copy, Debug, Default)]
pub struct ProbeDigest {
    /// Probes scored (not skipped).
    pub frames: u64,
    /// Mean PSNR (dB) of served vs dense reference.
    pub psnr_mean_db: f64,
    /// Worst PSNR (dB) observed.
    pub psnr_min_db: f64,
    /// Mean SSIM of served vs dense reference.
    pub ssim_mean: f64,
}

#[derive(Default)]
struct DigestAccum {
    frames: u64,
    psnr_sum_db: f64,
    psnr_min_db: f64,
    ssim_sum: f64,
}

/// Everything the async probe job needs, behind one mutex: its own
/// renderer clone (shares scene + pool with the session), persistent
/// reference frame + scratch, and the copied served RGB + pose + rung.
struct ProbeState {
    renderer: Renderer,
    reference: Frame,
    scratch: FrameScratch,
    served: Vec<f32>,
    pose: Pose,
    level: u8,
}

/// Asynchronous served-vs-reference quality scorer for one session.
pub struct QualityProbe {
    /// Score every Nth warped frame (≥ 1 once constructed).
    interval: u64,
    warped_seen: u64,
    pool: Arc<WorkerPool>,
    /// At most one probe render in flight; `swap` is the launch gate.
    inflight: Arc<AtomicBool>,
    state: Arc<Mutex<ProbeState>>,
    accum: Arc<Mutex<DigestAccum>>,
}

impl QualityProbe {
    /// Build a probe over the session's renderer. The clone shares the
    /// scene handle and worker pool; buffers are allocated up front so
    /// steady-state probing reuses them.
    pub fn new(interval: usize, renderer: &Renderer) -> QualityProbe {
        let (w, h) = (renderer.intrinsics().width, renderer.intrinsics().height);
        let renderer = renderer.clone();
        let pool = renderer.worker_pool();
        QualityProbe {
            interval: interval.max(1) as u64,
            warped_seen: 0,
            pool,
            inflight: Arc::new(AtomicBool::new(false)),
            state: Arc::new(Mutex::new(ProbeState {
                renderer,
                reference: Frame::new(w, h),
                scratch: FrameScratch::new(),
                served: Vec::with_capacity(w * h * 3),
                pose: Pose::IDENTITY,
                level: 0,
            })),
            accum: Arc::new(Mutex::new(DigestAccum::default())),
        }
    }

    /// Observe one served warped frame; every `interval`th call tries to
    /// launch a probe. Skips (and counts the skip) when a probe is
    /// already in flight or the pool has no idle worker — the serving
    /// path is never made to wait on quality accounting.
    pub fn observe_warped(&mut self, served: &Frame, pose: &Pose, level: u8) {
        self.warped_seen += 1;
        if self.warped_seen % self.interval != 0 {
            return;
        }
        if self.inflight.swap(true, Ordering::AcqRel) {
            hub().probe_skipped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        if self.pool.idle_capacity() == 0 {
            self.inflight.store(false, Ordering::Release);
            hub().probe_skipped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        {
            let mut st = self.state.lock().unwrap();
            st.served.clear();
            st.served.extend_from_slice(&served.rgb);
            st.pose = *pose;
            st.level = level;
        }
        let state = Arc::clone(&self.state);
        let accum = Arc::clone(&self.accum);
        let inflight = Arc::clone(&self.inflight);
        self.pool.submit(move || {
            score_probe(&state, &accum);
            inflight.store(false, Ordering::Release);
        });
    }

    /// Digest of every probe scored so far (all-zero before the first).
    pub fn digest(&self) -> ProbeDigest {
        let a = self.accum.lock().unwrap();
        if a.frames == 0 {
            return ProbeDigest::default();
        }
        ProbeDigest {
            frames: a.frames,
            psnr_mean_db: a.psnr_sum_db / a.frames as f64,
            psnr_min_db: a.psnr_min_db,
            ssim_mean: a.ssim_sum / a.frames as f64,
        }
    }

    /// Spin until no probe is in flight (tests / example shutdown).
    pub fn drain(&self) {
        while self.inflight.load(Ordering::Acquire) {
            std::thread::yield_now();
        }
    }
}

/// The pool-side half: render the dense reference and score it against
/// the copied served frame, feeding the hub and the digest accumulator.
/// Nested `parallel_for` inside a boxed pool job is safe — it falls back
/// inline when the gang is busy (`util/pool.rs`).
fn score_probe(state: &Mutex<ProbeState>, accum: &Mutex<DigestAccum>) {
    let mut guard = state.lock().unwrap();
    let st = &mut *guard;
    let pose = st.pose;
    st.renderer
        .execute(&pose, &mut st.reference, RenderPass::Dense, &mut st.scratch);
    let (w, h) = (st.reference.width, st.reference.height);
    let psnr_db = crate::metrics::psnr(&st.served, &st.reference.rgb).clamp(0.0, PSNR_CAP_DB);
    let ssim = crate::metrics::ssim(&st.served, &st.reference.rgb, w, h).clamp(0.0, 1.0);
    hub().record_probe(
        st.level,
        (psnr_db * 100.0).round() as u64,
        (ssim * 1000.0).round() as u64,
    );
    drop(guard);
    let mut a = accum.lock().unwrap();
    a.frames += 1;
    a.psnr_sum_db += psnr_db;
    a.psnr_min_db = if a.frames == 1 {
        psnr_db
    } else {
        a.psnr_min_db.min(psnr_db)
    };
    a.ssim_sum += ssim;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scene::generate;

    #[test]
    fn probe_scores_identical_frames_at_the_cap() {
        let scene = generate("probe_unit", 0.05, 48, 48);
        let renderer =
            Renderer::from_assets(std::sync::Arc::new(crate::scene::SceneAssets::from_scene(&scene)));
        let pose = scene.sample_poses(1)[0];
        let (frame, _) = renderer.render(&pose);

        let mut probe = QualityProbe::new(1, &renderer);
        let before = hub().probe_frames.load(Ordering::Relaxed);
        probe.observe_warped(&frame, &pose, 2);
        probe.drain();
        probe.pool.wait_idle();
        assert!(hub().probe_frames.load(Ordering::Relaxed) > before);

        let d = probe.digest();
        assert_eq!(d.frames, 1);
        // Served == reference: PSNR saturates at the cap, SSIM at 1.
        assert!(
            d.psnr_mean_db > 90.0 && d.ssim_mean > 0.99,
            "identical-frame probe scored psnr={} ssim={}",
            d.psnr_mean_db,
            d.ssim_mean
        );
        assert_eq!(d.psnr_min_db, d.psnr_mean_db);
    }

    #[test]
    fn interval_gates_launches() {
        let scene = generate("probe_gate", 0.05, 48, 48);
        let renderer =
            Renderer::from_assets(std::sync::Arc::new(crate::scene::SceneAssets::from_scene(&scene)));
        let pose = scene.sample_poses(1)[0];
        let (frame, _) = renderer.render(&pose);

        let mut probe = QualityProbe::new(4, &renderer);
        for _ in 0..3 {
            probe.observe_warped(&frame, &pose, 0);
        }
        probe.drain();
        probe.pool.wait_idle();
        assert_eq!(probe.digest().frames, 0, "interval 4 must not fire in 3 frames");
        probe.observe_warped(&frame, &pose, 0);
        probe.drain();
        probe.pool.wait_idle();
        assert_eq!(probe.digest().frames, 1);
    }
}
