//! Snapshot assembly + exposition writers.
//!
//! [`TelemetrySnapshot`] is the read-side aggregate over the whole
//! serving stack — process-wide hub totals, per-scene residency and
//! size-class load latency, per-session ring windows — assembled by
//! [`StreamServer::telemetry_snapshot`](crate::serve::StreamServer::telemetry_snapshot).
//! Two writers, no new crates: [`TelemetrySnapshot::to_json`] on the
//! in-repo [`Json`] tree, and [`TelemetrySnapshot::to_prometheus`]
//! emitting Prometheus text exposition (counters as `_total`, histogram
//! digests as `quantile`-labelled gauges).

use super::hist::HistSummary;
use super::hub::{hub, MetricsHub, QUALITY_RUNGS};
use super::probe::ProbeDigest;
use super::ring::RingSummary;
use crate::util::json::Json;
use std::fmt::Write as _;
use std::sync::atomic::Ordering;

/// Labels for the shard size classes, index-aligned with
/// [`SizeClass`](crate::shard::SizeClass).
pub const SIZE_CLASS_LABELS: [&str; 3] = ["small", "medium", "large"];

/// Process-wide totals and distributions captured from the hub.
#[derive(Clone, Copy, Debug, Default)]
pub struct NodeTelemetry {
    pub frames: u64,
    pub full_frames: u64,
    pub warped_frames: u64,
    pub stalled_steps: u64,
    pub shard_loads: u64,
    pub governor_evictions: u64,
    /// QoS ladder degradations across all sessions (see
    /// [`qos`](crate::serve::qos)).
    pub qos_level_downs: u64,
    /// QoS ladder promotions across all sessions.
    pub qos_level_ups: u64,
    /// Queued poses shed by the paced scheduler from stalled sessions.
    pub qos_shed_frames: u64,
    /// Sessions refused by the admission policy.
    pub qos_rejected_sessions: u64,
    /// Sessions admitted pre-degraded at the bottom ladder rung.
    pub qos_downtiered_sessions: u64,
    /// Masked passes served incrementally from the temporal plan cache.
    pub plan_cache_hits: u64,
    /// Masked passes that fell back to a full re-plan (cold cache or
    /// pose drift beyond the guard-band bound).
    pub plan_cache_fallbacks: u64,
    pub frame_ns: HistSummary,
    pub lateness_ns: HistSummary,
    pub queue_wait_ns: HistSummary,
    pub imbalance_pm: HistSummary,
    pub masked_lane_pm: HistSummary,
    pub load_ns_mem: HistSummary,
    pub load_ns_file: HistSummary,
    /// Headroom left in the pacing interval per paced step, permille
    /// (QoS-enabled sessions only; 0 = overran).
    pub qos_headroom_pm: HistSummary,
    /// Fraction of active tiles re-binned per plan-cache hit, permille.
    pub plan_rebin_pm: HistSummary,
    /// Quality probes scored (dense reference rendered + compared).
    pub probe_frames: u64,
    /// Probes skipped for lack of idle pool capacity.
    pub probe_skipped: u64,
    /// Probe PSNR (served vs dense reference) per QoS rung, centi-dB.
    pub probe_psnr_cdb: [HistSummary; QUALITY_RUNGS],
    /// Probe SSIM per QoS rung, permille.
    pub probe_ssim_pm: [HistSummary; QUALITY_RUNGS],
}

impl NodeTelemetry {
    /// Digest the process-wide [`hub`].
    pub fn capture() -> NodeTelemetry {
        NodeTelemetry::from_hub(hub())
    }

    /// Digest an explicit hub (tests use a private one).
    pub fn from_hub(h: &MetricsHub) -> NodeTelemetry {
        NodeTelemetry {
            frames: h.frames.load(Ordering::Relaxed),
            full_frames: h.full_frames.load(Ordering::Relaxed),
            warped_frames: h.warped_frames.load(Ordering::Relaxed),
            stalled_steps: h.stalled_steps.load(Ordering::Relaxed),
            shard_loads: h.shard_loads.load(Ordering::Relaxed),
            governor_evictions: h.governor_evictions.load(Ordering::Relaxed),
            qos_level_downs: h.qos_level_downs.load(Ordering::Relaxed),
            qos_level_ups: h.qos_level_ups.load(Ordering::Relaxed),
            qos_shed_frames: h.qos_shed_frames.load(Ordering::Relaxed),
            qos_rejected_sessions: h.qos_rejected_sessions.load(Ordering::Relaxed),
            qos_downtiered_sessions: h.qos_downtiered_sessions.load(Ordering::Relaxed),
            plan_cache_hits: h.plan_cache_hits.load(Ordering::Relaxed),
            plan_cache_fallbacks: h.plan_cache_fallbacks.load(Ordering::Relaxed),
            frame_ns: h.frame_ns.summary(),
            lateness_ns: h.lateness_ns.summary(),
            queue_wait_ns: h.queue_wait_ns.summary(),
            imbalance_pm: h.imbalance_pm.summary(),
            masked_lane_pm: h.masked_lane_pm.summary(),
            load_ns_mem: h.load_ns_mem.summary(),
            load_ns_file: h.load_ns_file.summary(),
            qos_headroom_pm: h.qos_headroom_pm.summary(),
            plan_rebin_pm: h.plan_rebin_pm.summary(),
            probe_frames: h.probe_frames.load(Ordering::Relaxed),
            probe_skipped: h.probe_skipped.load(Ordering::Relaxed),
            probe_psnr_cdb: std::array::from_fn(|r| h.probe_psnr_cdb[r].summary()),
            probe_ssim_pm: std::array::from_fn(|r| h.probe_ssim_pm[r].summary()),
        }
    }
}

/// Per-scene aggregate: registry/residency stats plus size-class load
/// latency digests (all-zero summaries for monolithic scenes).
#[derive(Clone, Copy, Debug, Default)]
pub struct SceneTelemetry {
    pub scene: u32,
    /// `"monolithic"`, `"memory"`, or `"file"`.
    pub store: &'static str,
    pub sessions: u32,
    pub shards: u32,
    pub resident_bytes: u64,
    pub pinned_bytes: u64,
    pub lifetime_loads: u64,
    pub lifetime_evictions: u64,
    pub evicted_by_peers: u64,
    /// Shard load latency by size class, index-aligned with
    /// [`SIZE_CLASS_LABELS`] (nanoseconds).
    pub load_by_class: [HistSummary; 3],
}

/// Per-session aggregate: ring totals plus one window digest.
#[derive(Clone, Copy, Debug, Default)]
pub struct SessionTelemetry {
    pub session: usize,
    /// Scene this session renders (multi-scene servers).
    pub scene: Option<usize>,
    /// Lifetime frames stepped by this session.
    pub frames: u64,
    /// Current QoS ladder level (0 = full quality; see
    /// [`LADDER`](crate::serve::qos::LADDER)).
    pub qos_level: u8,
    /// Aggregates over the ring window.
    pub window: RingSummary,
    /// Online quality probe digest, when the session has scored probes
    /// (`probe_interval > 0`; see [`probe`](crate::telemetry::probe)).
    pub probe: Option<ProbeDigest>,
}

/// The full cross-layer aggregate; see module docs.
#[derive(Clone, Debug, Default)]
pub struct TelemetrySnapshot {
    pub node: NodeTelemetry,
    pub scenes: Vec<SceneTelemetry>,
    pub sessions: Vec<SessionTelemetry>,
}

fn ns_hist_json(s: &HistSummary) -> Json {
    let ms = |v: u64| v as f64 / 1e6;
    let mut j = Json::obj();
    j.set("count", s.count)
        .set("mean_ms", s.mean / 1e6)
        .set("p50_ms", ms(s.p50))
        .set("p95_ms", ms(s.p95))
        .set("p99_ms", ms(s.p99))
        .set("max_ms", ms(s.max));
    j
}

fn db_hist_json(s: &HistSummary) -> Json {
    let db = |v: u64| v as f64 / 1e2;
    let mut j = Json::obj();
    j.set("count", s.count)
        .set("mean_db", s.mean / 1e2)
        .set("p50_db", db(s.p50))
        .set("p95_db", db(s.p95))
        .set("p99_db", db(s.p99))
        .set("max_db", db(s.max));
    j
}

fn ratio_hist_json(s: &HistSummary) -> Json {
    let r = |v: u64| v as f64 / 1e3;
    let mut j = Json::obj();
    j.set("count", s.count)
        .set("mean", s.mean / 1e3)
        .set("p50", r(s.p50))
        .set("p95", r(s.p95))
        .set("p99", r(s.p99))
        .set("max", r(s.max));
    j
}

/// Emit one quantile-labelled gauge family from a summary.
fn prom_hist(out: &mut String, name: &str, labels: &str, s: &HistSummary, scale: f64) {
    if s.count == 0 {
        return;
    }
    let sep = if labels.is_empty() { "" } else { "," };
    for (q, v) in [(0.5, s.p50), (0.95, s.p95), (0.99, s.p99)] {
        let _ = writeln!(
            out,
            "{name}{{{labels}{sep}quantile=\"{q}\"}} {:.6}",
            v as f64 * scale
        );
    }
    if labels.is_empty() {
        let _ = writeln!(out, "{name}_count {}", s.count);
    } else {
        let _ = writeln!(out, "{name}_count{{{labels}}} {}", s.count);
    }
}

impl TelemetrySnapshot {
    /// JSON exposition over the in-repo [`Json`] tree.
    pub fn to_json(&self) -> Json {
        let n = &self.node;
        let mut node = Json::obj();
        node.set("frames", n.frames)
            .set("full_frames", n.full_frames)
            .set("warped_frames", n.warped_frames)
            .set("stalled_steps", n.stalled_steps)
            .set("shard_loads", n.shard_loads)
            .set("governor_evictions", n.governor_evictions)
            .set("qos_level_downs", n.qos_level_downs)
            .set("qos_level_ups", n.qos_level_ups)
            .set("qos_shed_frames", n.qos_shed_frames)
            .set("qos_rejected_sessions", n.qos_rejected_sessions)
            .set("qos_downtiered_sessions", n.qos_downtiered_sessions)
            .set("plan_cache_hits", n.plan_cache_hits)
            .set("plan_cache_fallbacks", n.plan_cache_fallbacks)
            .set("plan_rebin_fraction", ratio_hist_json(&n.plan_rebin_pm))
            .set("qos_headroom", ratio_hist_json(&n.qos_headroom_pm))
            .set("frame_ms", ns_hist_json(&n.frame_ns))
            .set("lateness_ms", ns_hist_json(&n.lateness_ns))
            .set("queue_wait_ms", ns_hist_json(&n.queue_wait_ns))
            .set("imbalance", ratio_hist_json(&n.imbalance_pm))
            .set("masked_lane_fraction", ratio_hist_json(&n.masked_lane_pm))
            .set("load_ms_mem", ns_hist_json(&n.load_ns_mem))
            .set("load_ms_file", ns_hist_json(&n.load_ns_file));
        let mut probe = Json::obj();
        probe
            .set("frames", n.probe_frames)
            .set("skipped", n.probe_skipped);
        let mut psnr = Json::obj();
        let mut ssim = Json::obj();
        for rung in 0..QUALITY_RUNGS {
            if n.probe_psnr_cdb[rung].count > 0 {
                psnr.set(&format!("rung{rung}"), db_hist_json(&n.probe_psnr_cdb[rung]));
            }
            if n.probe_ssim_pm[rung].count > 0 {
                ssim.set(&format!("rung{rung}"), ratio_hist_json(&n.probe_ssim_pm[rung]));
            }
        }
        probe.set("psnr_db_by_rung", psnr).set("ssim_by_rung", ssim);
        node.set("probe", probe);

        let scenes: Vec<Json> = self
            .scenes
            .iter()
            .map(|sc| {
                let mut j = Json::obj();
                j.set("scene", sc.scene as usize)
                    .set("store", sc.store)
                    .set("sessions", sc.sessions as usize)
                    .set("shards", sc.shards as usize)
                    .set("resident_bytes", sc.resident_bytes)
                    .set("pinned_bytes", sc.pinned_bytes)
                    .set("lifetime_loads", sc.lifetime_loads)
                    .set("lifetime_evictions", sc.lifetime_evictions)
                    .set("evicted_by_peers", sc.evicted_by_peers);
                let mut classes = Json::obj();
                for (label, s) in SIZE_CLASS_LABELS.iter().zip(sc.load_by_class.iter()) {
                    if s.count > 0 {
                        classes.set(label, ns_hist_json(s));
                    }
                }
                j.set("load_ms_by_class", classes);
                j
            })
            .collect();

        let sessions: Vec<Json> = self
            .sessions
            .iter()
            .map(|se| {
                let w = &se.window;
                let mut j = Json::obj();
                j.set("session", se.session)
                    .set("frames", se.frames)
                    .set("qos_level", se.qos_level as usize)
                    .set("window_frames", w.frames)
                    .set("warped_frames", w.warped_frames)
                    .set("stalled", w.stalled)
                    .set("shards_loaded", w.shards_loaded)
                    .set("step_ms_mean", w.step_ms_mean)
                    .set("step_ms_p50", w.step_ms_p50)
                    .set("step_ms_p95", w.step_ms_p95)
                    .set("step_ms_p99", w.step_ms_p99)
                    .set("lateness_ms_p50", w.lateness_ms_p50)
                    .set("lateness_ms_p99", w.lateness_ms_p99)
                    .set("queue_ms_p50", w.queue_ms_p50)
                    .set("queue_ms_p99", w.queue_ms_p99)
                    .set("imbalance_mean", w.imbalance_mean)
                    .set("masked_lane_fraction_mean", w.masked_lane_fraction_mean)
                    .set("warped_fraction_mean", w.warped_fraction_mean)
                    .set("pairs_mean", w.pairs_mean);
                if let Some(scene) = se.scene {
                    j.set("scene", scene);
                }
                if let Some(p) = se.probe.filter(|p| p.frames > 0) {
                    j.set("probe_frames", p.frames)
                        .set("probe_psnr_mean_db", p.psnr_mean_db)
                        .set("probe_psnr_min_db", p.psnr_min_db)
                        .set("probe_ssim_mean", p.ssim_mean);
                }
                j
            })
            .collect();

        let mut root = Json::obj();
        root.set("node", node).set("scenes", scenes).set("sessions", sessions);
        root
    }

    /// Prometheus text exposition (the `lsg_` metric family).
    pub fn to_prometheus(&self) -> String {
        const NS_TO_MS: f64 = 1e-6;
        const PM_TO_RATIO: f64 = 1e-3;
        let mut out = String::with_capacity(2048);
        let n = &self.node;
        for (name, v) in [
            ("lsg_frames_total", n.frames),
            ("lsg_full_frames_total", n.full_frames),
            ("lsg_warped_frames_total", n.warped_frames),
            ("lsg_stalled_steps_total", n.stalled_steps),
            ("lsg_shard_loads_total", n.shard_loads),
            ("lsg_governor_evictions_total", n.governor_evictions),
            ("lsg_qos_level_downs_total", n.qos_level_downs),
            ("lsg_qos_level_ups_total", n.qos_level_ups),
            ("lsg_qos_shed_frames_total", n.qos_shed_frames),
            ("lsg_qos_rejected_sessions_total", n.qos_rejected_sessions),
            ("lsg_qos_downtiered_sessions_total", n.qos_downtiered_sessions),
            ("lsg_plan_cache_hits_total", n.plan_cache_hits),
            ("lsg_plan_cache_fallbacks_total", n.plan_cache_fallbacks),
            ("lsg_probe_frames_total", n.probe_frames),
            ("lsg_probe_skipped_total", n.probe_skipped),
        ] {
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {v}");
        }
        const CDB_TO_DB: f64 = 1e-2;
        for rung in 0..QUALITY_RUNGS {
            let labels = format!("rung=\"{rung}\"");
            prom_hist(
                &mut out,
                "lsg_probe_psnr_db",
                &labels,
                &n.probe_psnr_cdb[rung],
                CDB_TO_DB,
            );
            prom_hist(&mut out, "lsg_probe_ssim", &labels, &n.probe_ssim_pm[rung], 1e-3);
        }
        prom_hist(&mut out, "lsg_qos_headroom", "", &n.qos_headroom_pm, PM_TO_RATIO);
        prom_hist(&mut out, "lsg_plan_rebin_fraction", "", &n.plan_rebin_pm, PM_TO_RATIO);
        prom_hist(&mut out, "lsg_frame_ms", "", &n.frame_ns, NS_TO_MS);
        prom_hist(&mut out, "lsg_lateness_ms", "", &n.lateness_ns, NS_TO_MS);
        prom_hist(&mut out, "lsg_queue_wait_ms", "", &n.queue_wait_ns, NS_TO_MS);
        prom_hist(&mut out, "lsg_imbalance", "", &n.imbalance_pm, PM_TO_RATIO);
        prom_hist(
            &mut out,
            "lsg_masked_lane_fraction",
            "",
            &n.masked_lane_pm,
            PM_TO_RATIO,
        );
        prom_hist(&mut out, "lsg_load_ms", "store=\"memory\"", &n.load_ns_mem, NS_TO_MS);
        prom_hist(&mut out, "lsg_load_ms", "store=\"file\"", &n.load_ns_file, NS_TO_MS);

        for sc in &self.scenes {
            let scene = sc.scene;
            let l = format!("scene=\"{scene}\"");
            for (name, v) in [
                ("lsg_scene_sessions", sc.sessions as u64),
                ("lsg_scene_shards", sc.shards as u64),
                ("lsg_scene_resident_bytes", sc.resident_bytes),
                ("lsg_scene_pinned_bytes", sc.pinned_bytes),
                ("lsg_scene_loads_total", sc.lifetime_loads),
                ("lsg_scene_evictions_total", sc.lifetime_evictions),
                ("lsg_scene_evicted_by_peers_total", sc.evicted_by_peers),
            ] {
                let _ = writeln!(out, "{name}{{{l}}} {v}");
            }
            for (label, s) in SIZE_CLASS_LABELS.iter().zip(sc.load_by_class.iter()) {
                let labels = format!("scene=\"{scene}\",class=\"{label}\"");
                prom_hist(&mut out, "lsg_scene_load_ms", &labels, s, NS_TO_MS);
            }
        }

        for se in &self.sessions {
            let session = se.session;
            let l = format!("session=\"{session}\"");
            let w = &se.window;
            let _ = writeln!(out, "lsg_session_frames_total{{{l}}} {}", se.frames);
            let _ = writeln!(out, "lsg_session_qos_level{{{l}}} {}", se.qos_level);
            let _ = writeln!(out, "lsg_session_window_stalls{{{l}}} {}", w.stalled);
            for (name, v) in [
                ("lsg_session_step_ms", [w.step_ms_p50, w.step_ms_p95, w.step_ms_p99]),
                (
                    "lsg_session_lateness_ms",
                    [w.lateness_ms_p50, w.lateness_ms_p99, w.lateness_ms_p99],
                ),
                (
                    "lsg_session_queue_ms",
                    [w.queue_ms_p50, w.queue_ms_p99, w.queue_ms_p99],
                ),
            ] {
                for (q, v) in [(0.5, v[0]), (0.95, v[1]), (0.99, v[2])] {
                    let _ = writeln!(out, "{name}{{{l},quantile=\"{q}\"}} {v:.6}");
                }
            }
            let _ = writeln!(
                out,
                "lsg_session_warped_fraction{{{l}}} {:.6}",
                w.warped_fraction_mean
            );
            let _ = writeln!(out, "lsg_session_imbalance{{{l}}} {:.6}", w.imbalance_mean);
            if let Some(p) = se.probe.filter(|p| p.frames > 0) {
                let _ = writeln!(out, "lsg_session_probe_frames_total{{{l}}} {}", p.frames);
                let _ = writeln!(
                    out,
                    "lsg_session_probe_psnr_mean_db{{{l}}} {:.6}",
                    p.psnr_mean_db
                );
                let _ = writeln!(
                    out,
                    "lsg_session_probe_psnr_min_db{{{l}}} {:.6}",
                    p.psnr_min_db
                );
                let _ = writeln!(out, "lsg_session_probe_ssim_mean{{{l}}} {:.6}", p.ssim_mean);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::hist::Histogram;

    fn sample_snapshot() -> TelemetrySnapshot {
        let hub = MetricsHub::new();
        for i in 1..=100u64 {
            hub.record_frame(i % 5 == 0, i * 1_000_000);
            hub.record_sched(i * 10_000, i * 1_000, i > 95);
            hub.record_shard_load(i % 2 == 0, i * 50_000);
        }
        hub.imbalance_pm.record(1_250);
        hub.masked_lane_pm.record(120);
        hub.qos_level_downs.fetch_add(3, Ordering::Relaxed);
        hub.qos_level_ups.fetch_add(2, Ordering::Relaxed);
        hub.qos_shed_frames.fetch_add(7, Ordering::Relaxed);
        hub.qos_rejected_sessions.fetch_add(1, Ordering::Relaxed);
        hub.qos_headroom_pm.record(450);
        hub.plan_cache_hits.fetch_add(12, Ordering::Relaxed);
        hub.plan_cache_fallbacks.fetch_add(4, Ordering::Relaxed);
        hub.plan_rebin_pm.record(250);
        hub.record_probe(0, 3_400, 980); // 34 dB / 0.98 at full quality
        hub.record_probe(2, 2_800, 910); // degraded rung pays in PSNR
        hub.probe_skipped.fetch_add(1, Ordering::Relaxed);
        let class_hist = Histogram::new();
        for i in 1..=10u64 {
            class_hist.record(i * 100_000);
        }
        let mut ring = crate::telemetry::FrameRing::with_capacity(64);
        for i in 1..=50u64 {
            ring.push(crate::telemetry::FrameRecord {
                frame_idx: i,
                warped: i % 5 != 0,
                step_ns: i * 2_000_000,
                lateness_ns: i * 10_000,
                stalled: i > 48,
                imbalance_pm: 1_100,
                pairs: 1_000,
                ..Default::default()
            });
        }
        TelemetrySnapshot {
            node: NodeTelemetry::from_hub(&hub),
            scenes: vec![SceneTelemetry {
                scene: 0,
                store: "memory",
                sessions: 2,
                shards: 16,
                resident_bytes: 1 << 20,
                pinned_bytes: 1 << 18,
                lifetime_loads: 40,
                lifetime_evictions: 8,
                evicted_by_peers: 1,
                load_by_class: [class_hist.summary(), HistSummary::default(), HistSummary::default()],
            }],
            sessions: vec![SessionTelemetry {
                session: 0,
                scene: Some(0),
                frames: ring.total(),
                qos_level: 1,
                window: ring.summary(64),
                probe: Some(ProbeDigest {
                    frames: 2,
                    psnr_mean_db: 31.0,
                    psnr_min_db: 28.0,
                    ssim_mean: 0.945,
                }),
            }],
        }
    }

    #[test]
    fn json_writer_round_trips_and_carries_percentiles() {
        let snap = sample_snapshot();
        let j = snap.to_json();
        // Round-trip through the in-repo parser.
        let parsed = Json::parse(&j.to_string_pretty()).expect("self-emitted json parses");
        let node = parsed.get("node").expect("node section");
        assert_eq!(node.get("frames").and_then(Json::as_f64), Some(100.0));
        let frame_ms = node.get("frame_ms").expect("frame_ms digest");
        let p50 = frame_ms.get("p50_ms").and_then(Json::as_f64).unwrap();
        let p99 = frame_ms.get("p99_ms").and_then(Json::as_f64).unwrap();
        assert!(p50 > 40.0 && p50 < 60.0, "p50_ms {p50}");
        assert!(p99 > 90.0 && p99 <= 115.0, "p99_ms {p99}");
        let scenes = parsed.get("scenes").and_then(Json::as_arr).unwrap();
        assert_eq!(scenes.len(), 1);
        let classes = scenes[0].get("load_ms_by_class").unwrap();
        assert!(classes.get("small").is_some(), "measured class present");
        assert!(classes.get("large").is_none(), "empty class omitted");
        let sessions = parsed.get("sessions").and_then(Json::as_arr).unwrap();
        let s0 = &sessions[0];
        assert_eq!(s0.get("window_frames").and_then(Json::as_f64), Some(50.0));
        assert_eq!(s0.get("qos_level").and_then(Json::as_f64), Some(1.0));
        assert!(s0.get("step_ms_p99").and_then(Json::as_f64).unwrap() > 0.0);
        assert!(s0.get("lateness_ms_p50").and_then(Json::as_f64).unwrap() > 0.0);
        assert_eq!(node.get("qos_level_downs").and_then(Json::as_f64), Some(3.0));
        assert_eq!(node.get("qos_shed_frames").and_then(Json::as_f64), Some(7.0));
        let headroom = node.get("qos_headroom").expect("qos_headroom digest");
        assert_eq!(headroom.get("p50").and_then(Json::as_f64), Some(0.45));
        assert_eq!(node.get("plan_cache_hits").and_then(Json::as_f64), Some(12.0));
        assert_eq!(node.get("plan_cache_fallbacks").and_then(Json::as_f64), Some(4.0));
        let rebin = node.get("plan_rebin_fraction").expect("plan_rebin_fraction digest");
        assert_eq!(rebin.get("p50").and_then(Json::as_f64), Some(0.25));
        // Probe attribution: measured rungs present, unmeasured omitted.
        let probe = node.get("probe").expect("probe section");
        assert_eq!(probe.get("frames").and_then(Json::as_f64), Some(2.0));
        assert_eq!(probe.get("skipped").and_then(Json::as_f64), Some(1.0));
        let psnr = probe.get("psnr_db_by_rung").unwrap();
        let rung0_p50 = psnr
            .get("rung0")
            .unwrap()
            .get("p50_db")
            .and_then(Json::as_f64)
            .unwrap();
        assert!(
            (30.0..40.0).contains(&rung0_p50),
            "rung0 p50_db {rung0_p50} (recorded 34 dB, ≤1/8 bucket error)"
        );
        assert_eq!(
            psnr.get("rung0").unwrap().get("mean_db").and_then(Json::as_f64),
            Some(34.0),
            "mean is exact"
        );
        assert!(psnr.get("rung2").is_some());
        assert!(psnr.get("rung1").is_none(), "unmeasured rung omitted");
        let ssim = probe.get("ssim_by_rung").unwrap();
        assert_eq!(
            ssim.get("rung0").unwrap().get("mean").and_then(Json::as_f64),
            Some(0.98)
        );
        // Per-session probe digest rides the session object.
        assert_eq!(s0.get("probe_frames").and_then(Json::as_f64), Some(2.0));
        assert_eq!(s0.get("probe_psnr_min_db").and_then(Json::as_f64), Some(28.0));
    }

    #[test]
    fn prometheus_writer_emits_expected_families() {
        let snap = sample_snapshot();
        let text = snap.to_prometheus();
        for needle in [
            "# TYPE lsg_frames_total counter",
            "lsg_frames_total 100",
            "lsg_stalled_steps_total 5",
            "lsg_frame_ms{quantile=\"0.5\"}",
            "lsg_lateness_ms{quantile=\"0.99\"}",
            "lsg_load_ms{store=\"memory\",quantile=\"0.5\"}",
            "lsg_load_ms{store=\"file\",quantile=\"0.99\"}",
            "lsg_scene_resident_bytes{scene=\"0\"}",
            "lsg_scene_load_ms{scene=\"0\",class=\"small\",quantile=\"0.5\"}",
            "lsg_session_step_ms{session=\"0\",quantile=\"0.99\"}",
            "lsg_session_lateness_ms{session=\"0\",quantile=\"0.5\"}",
            "lsg_qos_level_downs_total 3",
            "lsg_qos_shed_frames_total 7",
            "lsg_qos_rejected_sessions_total 1",
            "lsg_qos_headroom{quantile=\"0.5\"}",
            "lsg_session_qos_level{session=\"0\"} 1",
            "lsg_plan_cache_hits_total 12",
            "lsg_plan_cache_fallbacks_total 4",
            "lsg_plan_rebin_fraction{quantile=\"0.5\"}",
            "lsg_probe_frames_total 2",
            "lsg_probe_skipped_total 1",
            "lsg_probe_psnr_db{rung=\"0\",quantile=\"0.5\"}",
            "lsg_probe_psnr_db{rung=\"2\",quantile=\"0.99\"}",
            "lsg_probe_ssim{rung=\"0\",quantile=\"0.5\"}",
            "lsg_session_probe_frames_total{session=\"0\"} 2",
            "lsg_session_probe_psnr_mean_db{session=\"0\"} 31.0",
        ] {
            assert!(text.contains(needle), "missing {needle:?} in:\n{text}");
        }
        // Unmeasured families stay silent (no NaN/zero-count spam).
        assert!(!text.contains("class=\"large\""));
        assert!(!text.contains("rung=\"1\""), "unmeasured probe rung emitted");
        // Every line is `name{labels} value` or a comment.
        for line in text.lines() {
            assert!(
                line.starts_with('#')
                    || line
                        .split_once(' ')
                        .map(|(_, v)| v.parse::<f64>().is_ok())
                        .unwrap_or(false),
                "malformed exposition line: {line}"
            );
        }
    }
}
