//! Admin HTTP endpoint: the node's live introspection surface.
//!
//! A minimal, std-only HTTP/1.1 server (`std::net::TcpListener`, one
//! accept thread + a small bounded handler pool) that exposes what the
//! telemetry layer already computes — it performs **no** aggregation of
//! its own and never takes a render-path lock. The serving tier
//! ([`StreamServer::publish_admin`](crate::serve::StreamServer::publish_admin))
//! periodically renders its snapshot into the endpoint's published
//! state; handler threads serve those strings verbatim. A scrape
//! therefore costs one small mutex clone, and a stalled or hostile
//! client can never back-pressure the frame loop.
//!
//! Routes:
//!
//! | route                 | serves                                        |
//! |-----------------------|-----------------------------------------------|
//! | `GET /metrics`        | Prometheus exposition (last publish)          |
//! | `GET /snapshot.json`  | full [`TelemetrySnapshot`] JSON               |
//! | `GET /sessions`       | per-session ring digests + QoS level          |
//! | `GET /healthz`        | liveness (503 on sustained overload)          |
//! | `GET /readyz`         | readiness (budget / admission / stall gates)  |
//! | `GET /flightrecord`   | black-box dump ([`flight::dump_json`])        |
//! | `POST /trace/start`   | arm the span tracer (`?path=out.json`)        |
//! | `POST /trace/stop`    | flush + disarm the tracer                     |
//!
//! Enable via [`AdminConfig`] (`enabled`, default **off**) or the
//! `LSG_ADMIN=<addr>` env override; `docs/OBSERVABILITY.md` documents
//! every route with curl examples.
//!
//! [`TelemetrySnapshot`]: crate::telemetry::TelemetrySnapshot

use super::{flight, trace};
use crate::util::json::Json;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Handler threads serving parsed connections.
const HANDLER_THREADS: usize = 2;
/// Accepted connections queued for a handler before new ones get 503.
const QUEUE_DEPTH: usize = 8;
/// Per-connection socket timeout (read and write).
const IO_TIMEOUT: Duration = Duration::from_secs(2);
/// Request head (request line + headers) size cap.
const MAX_HEAD_BYTES: usize = 8 * 1024;

/// Endpoint configuration. Disabled by default: enabling it binds a
/// socket, which a render-only deployment should have to opt into.
#[derive(Clone, Debug)]
pub struct AdminConfig {
    /// Bind address, e.g. `127.0.0.1:9151`. Port 0 picks an ephemeral
    /// port (the bound address is reported by [`AdminServer::local_addr`]).
    pub addr: String,
    pub enabled: bool,
}

impl Default for AdminConfig {
    fn default() -> AdminConfig {
        AdminConfig {
            addr: "127.0.0.1:0".to_string(),
            enabled: false,
        }
    }
}

impl AdminConfig {
    /// Apply the `LSG_ADMIN=<addr>` env override: when set (non-empty),
    /// the endpoint is enabled on that address regardless of config.
    pub fn from_env(mut self) -> AdminConfig {
        if let Ok(addr) = std::env::var("LSG_ADMIN") {
            if !addr.is_empty() {
                self.addr = addr;
                self.enabled = true;
            }
        }
        self
    }
}

/// Readiness/liveness gates, permille. A publish evaluates the node
/// against these (see [`HealthReport::evaluate`]); the endpoint serves
/// the verdict.
#[derive(Clone, Copy, Debug)]
pub struct HealthThresholds {
    /// `/readyz` fails when resident bytes exceed this fraction of the
    /// governor budget (residency pressure ⇒ imminent eviction storms).
    pub max_budget_pm: u32,
    /// `/readyz` fails when active sessions reach this fraction of the
    /// admission ceiling (`max_sessions`); unlimited ceilings never trip.
    pub max_session_fill_pm: u32,
    /// `/readyz` fails when this fraction of sessions stalled within
    /// their recent ring window.
    pub max_stalled_pm: u32,
    /// `/healthz` (liveness) fails only past this harsher stall bound —
    /// the node is up but no longer meeting deadlines at all.
    pub live_stalled_pm: u32,
}

impl Default for HealthThresholds {
    fn default() -> HealthThresholds {
        HealthThresholds {
            max_budget_pm: 950,
            max_session_fill_pm: 1000,
            max_stalled_pm: 500,
            live_stalled_pm: 900,
        }
    }
}

/// One evaluated health verdict, published alongside the snapshot.
#[derive(Clone, Debug)]
pub struct HealthReport {
    /// Liveness: the node is serving frames sanely.
    pub healthy: bool,
    /// Readiness: the node can take more load.
    pub ready: bool,
    /// Human-readable reason for the first failed gate (empty when ok).
    pub reason: String,
    /// Observed stalled-session fraction, permille.
    pub stalled_pm: u32,
    /// Observed governor budget utilization, permille.
    pub budget_pm: u32,
    /// Observed admission fill (sessions / max_sessions), permille;
    /// 0 when the ceiling is unlimited.
    pub session_fill_pm: u32,
}

impl Default for HealthReport {
    fn default() -> HealthReport {
        HealthReport {
            healthy: true,
            ready: true,
            reason: String::new(),
            stalled_pm: 0,
            budget_pm: 0,
            session_fill_pm: 0,
        }
    }
}

impl HealthReport {
    /// Gate the observed permille signals against `t`.
    pub fn evaluate(
        t: &HealthThresholds,
        stalled_pm: u32,
        budget_pm: u32,
        session_fill_pm: u32,
    ) -> HealthReport {
        let mut r = HealthReport {
            stalled_pm,
            budget_pm,
            session_fill_pm,
            ..HealthReport::default()
        };
        if stalled_pm > t.live_stalled_pm {
            r.healthy = false;
            r.reason = format!(
                "stalled-session fraction {stalled_pm}pm past liveness bound {}pm",
                t.live_stalled_pm
            );
        }
        if r.reason.is_empty() && stalled_pm > t.max_stalled_pm {
            r.reason = format!(
                "stalled-session fraction {stalled_pm}pm past {}pm",
                t.max_stalled_pm
            );
        }
        if r.reason.is_empty() && budget_pm > t.max_budget_pm {
            r.reason = format!("governor budget {budget_pm}pm past {}pm", t.max_budget_pm);
        }
        if r.reason.is_empty() && session_fill_pm >= t.max_session_fill_pm && session_fill_pm > 0 {
            r.reason = format!(
                "admission fill {session_fill_pm}pm at ceiling {}pm",
                t.max_session_fill_pm
            );
        }
        r.ready = r.healthy && r.reason.is_empty();
        r
    }

    fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("healthy", self.healthy);
        j.set("ready", self.ready);
        j.set("reason", self.reason.as_str());
        j.set("stalled_pm", self.stalled_pm as f64);
        j.set("budget_pm", self.budget_pm as f64);
        j.set("session_fill_pm", self.session_fill_pm as f64);
        j
    }
}

/// Snapshot strings the serving tier last published. Handlers clone the
/// field they serve under a short lock; publishes replace wholesale.
#[derive(Default)]
struct Published {
    prometheus: String,
    snapshot_json: String,
    sessions_json: String,
    health: HealthReport,
    seq: u64,
}

/// The running endpoint: accept thread + handler pool + published state.
pub struct AdminServer {
    addr: SocketAddr,
    published: Arc<Mutex<Published>>,
    requests: Arc<AtomicU64>,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    handlers: Vec<std::thread::JoinHandle<()>>,
}

impl AdminServer {
    /// Bind and start serving. Returns `Ok(None)` when the config (after
    /// any env override the caller applied) leaves the endpoint disabled.
    pub fn start(config: &AdminConfig) -> std::io::Result<Option<AdminServer>> {
        if !config.enabled {
            return Ok(None);
        }
        let listener = TcpListener::bind(&config.addr)?;
        let addr = listener.local_addr()?;
        let published = Arc::new(Mutex::new(Published::default()));
        let requests = Arc::new(AtomicU64::new(0));
        let shutdown = Arc::new(AtomicBool::new(false));

        let (tx, rx) = sync_channel::<TcpStream>(QUEUE_DEPTH);
        let rx = Arc::new(Mutex::new(rx));
        let mut handlers = Vec::new();
        for _ in 0..HANDLER_THREADS {
            let rx = Arc::clone(&rx);
            let published = Arc::clone(&published);
            let requests = Arc::clone(&requests);
            handlers.push(std::thread::spawn(move || handler_loop(&rx, &published, &requests)));
        }

        let accept = {
            let shutdown = Arc::clone(&shutdown);
            std::thread::spawn(move || accept_loop(&listener, &tx, &shutdown))
        };

        Ok(Some(AdminServer {
            addr,
            published,
            requests,
            shutdown,
            accept: Some(accept),
            handlers,
        }))
    }

    /// The bound address (resolves port 0 to the actual ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Requests answered so far (all routes, including 404/503).
    pub fn requests(&self) -> u64 {
        self.requests.load(Ordering::Relaxed)
    }

    /// Replace the published introspection state. Called by the serving
    /// tier after each `telemetry_snapshot()` render; scrapes between
    /// publishes serve the previous snapshot.
    pub fn publish(
        &self,
        prometheus: String,
        snapshot_json: String,
        sessions_json: String,
        health: HealthReport,
    ) {
        let mut p = self.published.lock().unwrap();
        p.prometheus = prometheus;
        p.snapshot_json = snapshot_json;
        p.sessions_json = sessions_json;
        p.health = health;
        p.seq += 1;
    }
}

impl Drop for AdminServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // Wake the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        // Accept thread dropped its sender; handlers drain and exit.
        for h in self.handlers.drain(..) {
            let _ = h.join();
        }
    }
}

fn accept_loop(listener: &TcpListener, tx: &SyncSender<TcpStream>, shutdown: &AtomicBool) {
    for stream in listener.incoming() {
        if shutdown.load(Ordering::SeqCst) {
            return;
        }
        let stream = match stream {
            Ok(s) => s,
            Err(_) => continue,
        };
        let _ = stream.set_read_timeout(Some(IO_TIMEOUT));
        let _ = stream.set_write_timeout(Some(IO_TIMEOUT));
        match tx.try_send(stream) {
            Ok(()) => {}
            Err(TrySendError::Full(mut s)) => {
                // All handlers busy and the queue is full: shed the
                // scrape instead of queueing unboundedly.
                let _ = write_response(&mut s, 503, "text/plain", "overloaded\n");
            }
            Err(TrySendError::Disconnected(_)) => return,
        }
    }
}

fn handler_loop(
    rx: &Mutex<Receiver<TcpStream>>,
    published: &Mutex<Published>,
    requests: &AtomicU64,
) {
    loop {
        let stream = match rx.lock().unwrap().recv() {
            Ok(s) => s,
            Err(_) => return, // sender gone: shutting down
        };
        requests.fetch_add(1, Ordering::Relaxed);
        let mut stream = stream;
        let _ = handle_connection(&mut stream, published);
    }
}

/// Parsed request head: method + path + query (body is ignored; no
/// admin route consumes one).
struct Request {
    method: String,
    path: String,
    query: String,
}

fn read_request(stream: &mut TcpStream) -> std::io::Result<Option<Request>> {
    let mut buf = Vec::with_capacity(512);
    let mut chunk = [0u8; 512];
    loop {
        let n = stream.read(&mut chunk)?;
        if n == 0 {
            break;
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(4).any(|w| w == b"\r\n\r\n") || buf.len() > MAX_HEAD_BYTES {
            break;
        }
    }
    let head = String::from_utf8_lossy(&buf);
    let line = match head.lines().next() {
        Some(l) => l,
        None => return Ok(None),
    };
    let mut parts = line.split_whitespace();
    let (method, target) = match (parts.next(), parts.next()) {
        (Some(m), Some(t)) => (m.to_string(), t),
        _ => return Ok(None),
    };
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    Ok(Some(Request {
        method,
        path,
        query,
    }))
}

fn handle_connection(stream: &mut TcpStream, published: &Mutex<Published>) -> std::io::Result<()> {
    let req = match read_request(stream)? {
        Some(r) => r,
        None => return write_response(stream, 400, "text/plain", "bad request\n"),
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("GET", "/metrics") => {
            let (mut body, seq) = {
                let p = published.lock().unwrap();
                (p.prometheus.clone(), p.seq)
            };
            // Endpoint-own families, so the exposition is never empty —
            // a scrape before the first publish still yields metrics.
            let (events, anomalies, dumps) = flight::stats();
            body.push_str(&format!(
                "# TYPE lsg_admin_publish_seq gauge\nlsg_admin_publish_seq {seq}\n\
                 # TYPE lsg_flight_events_total counter\nlsg_flight_events_total {events}\n\
                 # TYPE lsg_flight_anomaly_triggers_total counter\n\
                 lsg_flight_anomaly_triggers_total {anomalies}\n\
                 # TYPE lsg_flight_dumps_total counter\nlsg_flight_dumps_total {dumps}\n"
            ));
            write_response(stream, 200, "text/plain; version=0.0.4", &body)
        }
        ("GET", "/snapshot.json") => {
            let body = {
                let p = published.lock().unwrap();
                if p.seq == 0 {
                    "{}".to_string()
                } else {
                    p.snapshot_json.clone()
                }
            };
            write_response(stream, 200, "application/json", &body)
        }
        ("GET", "/sessions") => {
            let body = {
                let p = published.lock().unwrap();
                if p.seq == 0 {
                    "[]".to_string()
                } else {
                    p.sessions_json.clone()
                }
            };
            write_response(stream, 200, "application/json", &body)
        }
        ("GET", "/healthz") => {
            let (health, _seq) = {
                let p = published.lock().unwrap();
                (p.health.clone(), p.seq)
            };
            let body = health.to_json().to_string_compact();
            // Liveness: answering at all is most of it; a published
            // report of sustained overload flips it to 503.
            let status = if health.healthy { 200 } else { 503 };
            write_response(stream, status, "application/json", &body)
        }
        ("GET", "/readyz") => {
            let (health, seq) = {
                let p = published.lock().unwrap();
                (p.health.clone(), p.seq)
            };
            if seq == 0 {
                return write_response(
                    stream,
                    503,
                    "application/json",
                    "{\"ready\":false,\"reason\":\"no snapshot published yet\"}",
                );
            }
            let body = health.to_json().to_string_compact();
            let status = if health.ready { 200 } else { 503 };
            write_response(stream, status, "application/json", &body)
        }
        ("GET", "/flightrecord") => {
            let body = flight::dump_json().to_string_compact();
            write_response(stream, 200, "application/json", &body)
        }
        ("POST", "/trace/start") => {
            let path = req
                .query
                .split('&')
                .find_map(|kv| kv.strip_prefix("path="))
                .filter(|p| !p.is_empty())
                .unwrap_or("lsg_admin_trace.json")
                .to_string();
            trace::start(&path);
            flight::note_trace_toggle(true);
            let mut j = Json::obj();
            j.set("tracing", true);
            j.set("path", path.as_str());
            write_response(stream, 200, "application/json", &j.to_string_compact())
        }
        ("POST", "/trace/stop") => {
            let written = trace::stop();
            flight::note_trace_toggle(false);
            let mut j = Json::obj();
            j.set("tracing", false);
            match &written {
                Some(p) => j.set("written", p.to_string_lossy().as_ref()),
                None => j.set("written", Json::Null),
            }
            write_response(stream, 200, "application/json", &j.to_string_compact())
        }
        _ => write_response(stream, 404, "text/plain", "not found\n"),
    }
}

fn write_response(
    stream: &mut TcpStream,
    status: u16,
    content_type: &str,
    body: &str,
) -> std::io::Result<()> {
    let reason = match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        503 => "Service Unavailable",
        _ => "OK",
    };
    let head = format!(
        "HTTP/1.1 {status} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_config_starts_nothing() {
        let server = AdminServer::start(&AdminConfig::default()).unwrap();
        assert!(server.is_none());
    }

    #[test]
    fn health_gates_fire_in_order() {
        let t = HealthThresholds::default();
        let ok = HealthReport::evaluate(&t, 0, 0, 0);
        assert!(ok.healthy && ok.ready && ok.reason.is_empty());

        let stalled = HealthReport::evaluate(&t, 600, 0, 0);
        assert!(stalled.healthy && !stalled.ready);
        assert!(stalled.reason.contains("stalled"));

        let dead = HealthReport::evaluate(&t, 950, 0, 0);
        assert!(!dead.healthy && !dead.ready);

        let squeezed = HealthReport::evaluate(&t, 0, 990, 0);
        assert!(squeezed.healthy && !squeezed.ready);
        assert!(squeezed.reason.contains("budget"));

        let full = HealthReport::evaluate(&t, 0, 0, 1000);
        assert!(full.healthy && !full.ready);
        assert!(full.reason.contains("admission"));
    }

    #[test]
    fn env_override_enables_and_retargets() {
        // Read-only check of the combinator (no env mutation: tests in
        // this binary run concurrently).
        let cfg = AdminConfig {
            addr: "127.0.0.1:7".into(),
            enabled: false,
        };
        assert!(!cfg.enabled);
        let on = AdminConfig {
            enabled: true,
            ..cfg.clone()
        };
        assert!(AdminServer::start(&AdminConfig::default()).unwrap().is_none());
        assert_eq!(on.addr, "127.0.0.1:7");
    }
}
