//! Black-box flight recorder: a process-global bounded event ring that
//! is always on, so the last few seconds of node behavior can be
//! reconstructed *after* something went wrong — without having had
//! `LSG_TRACE` armed in advance.
//!
//! Design mirrors [`FrameRing`](crate::telemetry::FrameRing): a fixed
//! [`FLIGHT_CAP`]-slot buffer of `Copy` events, overwritten in place
//! (alloc-free steady state; the one-time buffer reservation happens on
//! the first record). Producers are the paced scheduler (frame
//! completions, sheds), the QoS controller (ladder transitions), the
//! server admission gate, the residency governor (evictions), and the
//! shard load path (failures) — each a single short mutex push, never
//! on the session-lock or render-path critical sections.
//!
//! Three ways the box is opened:
//! * **on demand** — `GET /flightrecord` on the admin endpoint renders
//!   [`dump_json`];
//! * **on panic** — [`install_panic_hook`] chains a hook that writes the
//!   dump to the configured dump path before the process dies;
//! * **on anomaly** — [`note_paced`] keeps a sliding window of paced
//!   completions and auto-dumps when the window's p99 lateness breaches
//!   [`ANOMALY_LATENESS_MULT`]× the pacing interval or a stall burst
//!   exceeds [`ANOMALY_STALL_FRACTION`], rate-limited to one dump per
//!   fresh window.
//!
//! The dump path comes from `LSG_FLIGHT_DUMP=<path>` (boot default) or
//! [`set_dump_path`] at runtime; with no path configured, anomaly and
//! panic triggers still record [`FlightKind::AnomalyTrigger`] events and
//! bump counters, they just write no file.

use crate::util::json::Json;
use std::path::PathBuf;
use std::sync::{Mutex, Once, OnceLock};
use std::time::Instant;

/// Ring capacity: enough for several seconds of a busy node (every paced
/// frame is one event) while keeping the dump small enough to eyeball.
pub const FLIGHT_CAP: usize = 2048;

/// Sliding anomaly window, in paced completions.
pub const ANOMALY_WINDOW: usize = 64;

/// p99-lateness trigger: fires when the window's p99 lateness exceeds
/// this multiple of the session's pacing interval.
pub const ANOMALY_LATENESS_MULT: u64 = 4;

/// Stall-burst trigger: fires when more than this fraction (permille)
/// of the window stalled.
pub const ANOMALY_STALL_FRACTION_PM: u64 = 500;

/// Session id stamped on node-level events that have no session.
pub const NO_SESSION: u32 = u32::MAX;

/// What happened. Payload fields of [`FlightEvent`] are interpreted per
/// kind (see [`FlightEvent::value`] / [`FlightEvent::aux`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FlightKind {
    /// Paced frame completion: `value` = step ns, `aux` = lateness ns,
    /// `level` = QoS rung, `warped`/`stalled` flags.
    Frame,
    /// QoS ladder move: `level` = new rung, `aux` = old rung.
    QosTransition,
    /// Admission refused a session: `value` = active sessions.
    AdmissionReject,
    /// Admission admitted at the bottom rung: `value` = active sessions.
    AdmissionDownTier,
    /// Scheduler load shedding dropped queued poses: `value` = count.
    Shed,
    /// Governor evicted a shard: `session` = scene slot, `value` =
    /// freed bytes.
    GovernorEvict,
    /// A shard store load failed (before retry): `value` = shard id.
    ShardLoadFail,
    /// The anomaly detector fired: `value` = window p99 lateness ns (or
    /// stall count), `aux` = interval ns; `stalled` set for the
    /// stall-burst trigger.
    AnomalyTrigger,
    /// Runtime tracing toggled via the admin endpoint: `warped` flag
    /// reused as "now on".
    TraceToggle,
}

impl FlightKind {
    fn name(self) -> &'static str {
        match self {
            FlightKind::Frame => "frame",
            FlightKind::QosTransition => "qos_transition",
            FlightKind::AdmissionReject => "admission_reject",
            FlightKind::AdmissionDownTier => "admission_down_tier",
            FlightKind::Shed => "shed",
            FlightKind::GovernorEvict => "governor_evict",
            FlightKind::ShardLoadFail => "shard_load_fail",
            FlightKind::AnomalyTrigger => "anomaly_trigger",
            FlightKind::TraceToggle => "trace_toggle",
        }
    }
}

/// One ring slot. `Copy`, fixed size, no heap.
#[derive(Clone, Copy, Debug)]
pub struct FlightEvent {
    /// Monotone per-process sequence number (total events ever recorded
    /// reaches `seq + 1` at this event).
    pub seq: u64,
    /// Nanoseconds since the recorder's first event.
    pub ts_ns: u64,
    pub kind: FlightKind,
    /// Session (or scene slot for governor events); [`NO_SESSION`] for
    /// node-level events.
    pub session: u32,
    /// Primary payload, kind-specific (see [`FlightKind`]).
    pub value: u64,
    /// Secondary payload, kind-specific.
    pub aux: u64,
    /// QoS rung where meaningful.
    pub level: u8,
    pub warped: bool,
    pub stalled: bool,
}

struct FlightInner {
    buf: Vec<FlightEvent>,
    next: usize,
    len: usize,
    total: u64,
    // Anomaly sliding window (paced completions).
    window_lateness: [u64; ANOMALY_WINDOW],
    window_stalled: [bool; ANOMALY_WINDOW],
    window_next: usize,
    window_filled: usize,
    anomaly_triggers: u64,
    dumps_written: u64,
}

impl FlightInner {
    const fn new() -> FlightInner {
        FlightInner {
            buf: Vec::new(),
            next: 0,
            len: 0,
            total: 0,
            window_lateness: [0; ANOMALY_WINDOW],
            window_stalled: [false; ANOMALY_WINDOW],
            window_next: 0,
            window_filled: 0,
            anomaly_triggers: 0,
            dumps_written: 0,
        }
    }

    fn push(&mut self, mut ev: FlightEvent) {
        if self.buf.capacity() == 0 {
            // One-time reservation; every later push overwrites in place.
            self.buf.reserve_exact(FLIGHT_CAP);
        }
        ev.seq = self.total;
        self.total += 1;
        if self.len < FLIGHT_CAP {
            self.buf.push(ev);
            self.len += 1;
        } else {
            self.buf[self.next] = ev;
        }
        self.next = (self.next + 1) % FLIGHT_CAP;
    }

    /// Events oldest-first.
    fn iter_ordered(&self) -> impl Iterator<Item = &FlightEvent> {
        let start = if self.len < FLIGHT_CAP { 0 } else { self.next };
        (0..self.len).map(move |i| &self.buf[(start + i) % self.len.max(1)])
    }
}

static FLIGHT: Mutex<FlightInner> = Mutex::new(FlightInner::new());
static EPOCH: OnceLock<Instant> = OnceLock::new();
static DUMP_PATH: Mutex<Option<String>> = Mutex::new(None);
static DUMP_PATH_ENV: Once = Once::new();
static PANIC_HOOK: Once = Once::new();

fn now_ns() -> u64 {
    EPOCH
        .get_or_init(Instant::now)
        .elapsed()
        .as_nanos() as u64
}

fn event(kind: FlightKind, session: u32) -> FlightEvent {
    FlightEvent {
        seq: 0, // stamped by push
        ts_ns: now_ns(),
        kind,
        session,
        value: 0,
        aux: 0,
        level: 0,
        warped: false,
        stalled: false,
    }
}

/// Record an arbitrary event. The cheap producers below are preferred;
/// this is the escape hatch for one-off sites.
pub fn record(ev: FlightEvent) {
    if let Ok(mut f) = FLIGHT.lock() {
        f.push(ev);
    }
}

/// Paced frame completion (the scheduler's per-commit hook). Also feeds
/// the anomaly window; returns `true` when this observation fired the
/// anomaly trigger (and the auto-dump, when a dump path is configured).
pub fn note_paced(
    session: u32,
    step_ns: u64,
    lateness_ns: u64,
    interval_ns: u64,
    warped: bool,
    stalled: bool,
    level: u8,
) -> bool {
    let mut fired = false;
    let mut dump_path: Option<String> = None;
    if let Ok(mut f) = FLIGHT.lock() {
        let mut ev = event(FlightKind::Frame, session);
        ev.value = step_ns;
        ev.aux = lateness_ns;
        ev.level = level;
        ev.warped = warped;
        ev.stalled = stalled;
        f.push(ev);

        let i = f.window_next;
        f.window_lateness[i] = lateness_ns;
        f.window_stalled[i] = stalled;
        f.window_next = (f.window_next + 1) % ANOMALY_WINDOW;
        f.window_filled += 1;
        // Rate limit: only judge (and reset) on a full fresh window, so
        // one sustained incident produces one dump per window, not one
        // per frame.
        if f.window_filled >= ANOMALY_WINDOW && interval_ns > 0 {
            f.window_filled = 0;
            let mut lat = f.window_lateness;
            lat.sort_unstable();
            let p99 = lat[(ANOMALY_WINDOW * 99).div_ceil(100).min(ANOMALY_WINDOW) - 1];
            let stalls = f.window_stalled.iter().filter(|&&s| s).count() as u64;
            let stall_burst = stalls * 1000 > ANOMALY_STALL_FRACTION_PM * ANOMALY_WINDOW as u64;
            let late_breach = p99 > ANOMALY_LATENESS_MULT * interval_ns;
            if late_breach || stall_burst {
                fired = true;
                f.anomaly_triggers += 1;
                let mut ev = event(FlightKind::AnomalyTrigger, session);
                ev.value = if late_breach { p99 } else { stalls };
                ev.aux = interval_ns;
                ev.stalled = stall_burst && !late_breach;
                f.push(ev);
                dump_path = configured_dump_path();
            }
        }
    }
    if fired {
        if let Some(path) = dump_path {
            let _ = dump_to(&path);
        }
    }
    fired
}

/// QoS ladder transition.
pub fn note_qos_transition(session: u32, from: u8, to: u8) {
    let mut ev = event(FlightKind::QosTransition, session);
    ev.level = to;
    ev.aux = from as u64;
    record(ev);
}

/// Admission decision that bounded the node (reject or down-tier).
pub fn note_admission(rejected: bool, active_sessions: usize) {
    let kind = if rejected {
        FlightKind::AdmissionReject
    } else {
        FlightKind::AdmissionDownTier
    };
    let mut ev = event(kind, NO_SESSION);
    ev.value = active_sessions as u64;
    record(ev);
}

/// Scheduler load shedding dropped `count` queued poses of `session`.
pub fn note_shed(session: u32, count: u64) {
    let mut ev = event(FlightKind::Shed, session);
    ev.value = count;
    record(ev);
}

/// Governor evicted a shard from scene slot `slot`, freeing `bytes`.
pub fn note_governor_evict(slot: u32, bytes: u64) {
    let mut ev = event(FlightKind::GovernorEvict, slot);
    ev.value = bytes;
    record(ev);
}

/// A shard store load failed (first attempt; the caller retries once).
pub fn note_shard_load_fail(shard_id: u64) {
    let mut ev = event(FlightKind::ShardLoadFail, NO_SESSION);
    ev.value = shard_id;
    record(ev);
}

/// Runtime trace toggle (admin endpoint).
pub fn note_trace_toggle(on: bool) {
    let mut ev = event(FlightKind::TraceToggle, NO_SESSION);
    ev.warped = on;
    record(ev);
}

/// Lifetime `(events, anomaly_triggers, dumps_written)`.
pub fn stats() -> (u64, u64, u64) {
    FLIGHT
        .lock()
        .map(|f| (f.total, f.anomaly_triggers, f.dumps_written))
        .unwrap_or((0, 0, 0))
}

/// Reset the anomaly sliding window to empty (test/diagnostic hook —
/// the window is process-global, so a test asserting exact trigger
/// behavior clears residue from unrelated paced activity first). The
/// event ring and counters are untouched.
pub fn reset_anomaly_window() {
    if let Ok(mut f) = FLIGHT.lock() {
        f.window_lateness = [0; ANOMALY_WINDOW];
        f.window_stalled = [false; ANOMALY_WINDOW];
        f.window_next = 0;
        f.window_filled = 0;
    }
}

/// Set (or clear) the auto-dump path at runtime, overriding the
/// `LSG_FLIGHT_DUMP` boot default. Tests use this to avoid process-wide
/// env races.
pub fn set_dump_path(path: Option<&str>) {
    latch_env_dump_path();
    *DUMP_PATH.lock().unwrap() = path.map(str::to_string);
}

fn latch_env_dump_path() {
    DUMP_PATH_ENV.call_once(|| {
        if let Ok(p) = std::env::var("LSG_FLIGHT_DUMP") {
            if !p.is_empty() {
                *DUMP_PATH.lock().unwrap() = Some(p);
            }
        }
    });
}

/// The path anomaly/panic dumps write to, if any.
pub fn configured_dump_path() -> Option<String> {
    latch_env_dump_path();
    DUMP_PATH.lock().ok()?.clone()
}

/// Render the ring as a JSON document (oldest event first). Allocates;
/// strictly off the render path.
pub fn dump_json() -> Json {
    let mut doc = Json::obj();
    let mut events = Vec::new();
    if let Ok(f) = FLIGHT.lock() {
        doc.set("total_events", f.total)
            .set("dropped_events", f.total - f.len as u64)
            .set("anomaly_triggers", f.anomaly_triggers)
            .set("dumps_written", f.dumps_written);
        for e in f.iter_ordered() {
            let mut j = Json::obj();
            j.set("seq", e.seq)
                .set("t_ms", e.ts_ns as f64 / 1e6)
                .set("kind", e.kind.name());
            if e.session != NO_SESSION {
                j.set("session", e.session as u64);
            }
            match e.kind {
                FlightKind::Frame => {
                    j.set("step_ms", e.value as f64 / 1e6)
                        .set("lateness_ms", e.aux as f64 / 1e6)
                        .set("qos_level", e.level as u64)
                        .set("warped", e.warped)
                        .set("stalled", e.stalled);
                }
                FlightKind::QosTransition => {
                    j.set("from_level", e.aux).set("to_level", e.level as u64);
                }
                FlightKind::AdmissionReject | FlightKind::AdmissionDownTier => {
                    j.set("active_sessions", e.value);
                }
                FlightKind::Shed => {
                    j.set("dropped_poses", e.value);
                }
                FlightKind::GovernorEvict => {
                    j.set("scene", e.session as u64).set("freed_bytes", e.value);
                }
                FlightKind::ShardLoadFail => {
                    j.set("shard", e.value);
                }
                FlightKind::AnomalyTrigger => {
                    j.set("interval_ms", e.aux as f64 / 1e6).set(
                        if e.stalled { "window_stalls" } else { "p99_lateness_ms" },
                        if e.stalled {
                            Json::Num(e.value as f64)
                        } else {
                            Json::Num(e.value as f64 / 1e6)
                        },
                    );
                }
                FlightKind::TraceToggle => {
                    j.set("tracing_on", e.warped);
                }
            }
            events.push(j);
        }
    }
    doc.set("events", Json::Arr(events));
    doc
}

/// Write [`dump_json`] to `path` (pretty-printed) and count the dump.
pub fn dump_to(path: &str) -> std::io::Result<PathBuf> {
    let doc = dump_json();
    std::fs::write(path, doc.to_string_pretty())?;
    if let Ok(mut f) = FLIGHT.lock() {
        f.dumps_written += 1;
    }
    Ok(PathBuf::from(path))
}

/// Install a panic hook that writes the flight record to the configured
/// dump path before unwinding continues (chains the previous hook).
/// Idempotent; a no-op panic-time when no dump path is configured.
pub fn install_panic_hook() {
    PANIC_HOOK.call_once(|| {
        let prev = std::panic::take_hook();
        std::panic::set_hook(Box::new(move |info| {
            if let Some(path) = configured_dump_path() {
                let _ = dump_to(&path);
            }
            prev(info);
        }));
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    // The ring is process-global and other tests in this binary may
    // record concurrently, so assertions are monotone (counts only grow)
    // or keyed by the distinct payloads this test writes.

    #[test]
    fn ring_overwrites_in_place_and_keeps_order() {
        let (total_before, _, _) = stats();
        for i in 0..(FLIGHT_CAP as u64 + 10) {
            note_shed(7_777, i);
        }
        let (total, _, _) = stats();
        assert!(total - total_before >= FLIGHT_CAP as u64 + 10);
        let doc = dump_json();
        let events = doc.get("events").and_then(Json::as_arr).unwrap();
        assert!(events.len() <= FLIGHT_CAP);
        // Our shed events appear in increasing payload order.
        let mine: Vec<f64> = events
            .iter()
            .filter(|e| {
                e.str_or("kind", "") == "shed"
                    && e.f64_or("session", -1.0) == 7_777.0
            })
            .map(|e| e.f64_or("dropped_poses", -1.0))
            .collect();
        assert!(mine.len() > 2);
        assert!(mine.windows(2).all(|w| w[0] < w[1]), "ring order broken");
        // seq is monotone across the whole dump.
        let seqs: Vec<f64> = events.iter().map(|e| e.f64_or("seq", -1.0)).collect();
        assert!(seqs.windows(2).all(|w| w[0] < w[1]), "seq order broken");
    }

    // Exact anomaly-trigger behavior (one trigger per full dirty
    // window, none on clean windows) is asserted in `rust/tests/admin.rs`
    // where no other paced traffic shares the process-global window —
    // this binary's scheduler unit tests pace real sessions concurrently.
    #[test]
    fn note_paced_records_frame_events() {
        let (total_before, _, _) = stats();
        note_paced(11, 2_000_000, 0, 33_000_000, true, false, 1);
        let (total, _, _) = stats();
        assert!(total > total_before);
        let doc = dump_json();
        let events = doc.get("events").and_then(Json::as_arr).unwrap();
        assert!(events
            .iter()
            .any(|e| e.str_or("kind", "") == "frame" && e.f64_or("session", -1.0) == 11.0));
    }

    #[test]
    fn dump_round_trips_through_the_parser() {
        note_qos_transition(3, 0, 1);
        note_admission(true, 9);
        note_governor_evict(1, 4096);
        note_shard_load_fail(17);
        note_trace_toggle(true);
        let text = dump_json().to_string_pretty();
        let parsed = Json::parse(&text).expect("flight dump parses");
        let events = parsed.get("events").and_then(Json::as_arr).unwrap();
        for kind in [
            "qos_transition",
            "admission_reject",
            "governor_evict",
            "shard_load_fail",
            "trace_toggle",
        ] {
            assert!(
                events.iter().any(|e| e.str_or("kind", "") == kind),
                "missing {kind} event in dump"
            );
        }
    }
}
