//! Per-session bounded ring of committed frame records.
//!
//! Each [`StreamSession`](crate::coordinator::StreamSession) owns one
//! [`FrameRing`]: a preallocated circular buffer of `Copy` records, so
//! steady-state pushes are a slot overwrite — no allocation, ever. The
//! read side ([`FrameRing::summary`]) computes *exact* percentiles over
//! the last N frames by sorting a scratch copy; that path allocates and
//! is meant for snapshots/benches, not the frame loop. This replaces the
//! benches' ad-hoc per-frame accumulation with windowed queries any
//! consumer (snapshot exposition, future QoS loop) can share.

/// One committed frame, distilled from the step's `StepSummary`.
/// Scheduling fields are zero unless the step ran under the paced
/// [`SessionScheduler`](crate::coordinator::SessionScheduler), which
/// annotates the latest record after each commit.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct FrameRecord {
    /// Session-local frame index.
    pub frame_idx: u64,
    /// True for warped (TWSR / pixel) frames, false for dense renders.
    pub warped: bool,
    /// Wall-clock of the whole `step`.
    pub step_ns: u64,
    /// Pipeline stage splits (from `PassSummary`).
    pub preprocess_ns: u64,
    pub sort_ns: u64,
    pub rasterize_ns: u64,
    /// Scheduler lateness (finish − deadline), paced steps only.
    pub lateness_ns: u64,
    /// Scheduler queue wait (start − deadline), paced steps only.
    pub queue_ns: u64,
    /// Lateness exceeded the session interval.
    pub stalled: bool,
    /// Tile-splat pairs rasterized.
    pub pairs: u64,
    /// Shards loaded on the critical path of this frame.
    pub shards_loaded: u32,
    /// Measured plan imbalance, permille (0 when unplanned).
    pub imbalance_pm: u32,
    /// Masked SIMD lanes, permille of total lanes.
    pub masked_lane_pm: u32,
    /// Fraction of pixels carried by warping.
    pub warped_fraction: f32,
    /// QoS ladder level the frame was rendered at (0 = full quality).
    pub qos_level: u8,
}

/// Default ring capacity (frames) for a streaming session — at 30 FPS
/// about 17 seconds of history.
pub const DEFAULT_RING_CAP: usize = 512;

/// Bounded circular buffer of [`FrameRecord`]s.
pub struct FrameRing {
    buf: Vec<FrameRecord>,
    next: usize,
    len: usize,
    total: u64,
}

impl FrameRing {
    /// Preallocate a ring holding the last `cap` frames (min 1).
    pub fn with_capacity(cap: usize) -> FrameRing {
        FrameRing {
            buf: vec![FrameRecord::default(); cap.max(1)],
            next: 0,
            len: 0,
            total: 0,
        }
    }

    /// Append a record, overwriting the oldest once full. Allocation-free.
    #[inline]
    pub fn push(&mut self, rec: FrameRecord) {
        self.buf[self.next] = rec;
        self.next = (self.next + 1) % self.buf.len();
        self.len = (self.len + 1).min(self.buf.len());
        self.total += 1;
    }

    /// Records currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.len
    }

    /// No records yet.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Fixed slot count (oldest records overwritten past this).
    pub fn capacity(&self) -> usize {
        self.buf.len()
    }

    /// Lifetime frames pushed (including overwritten ones).
    pub fn total(&self) -> u64 {
        self.total
    }

    /// The most recently pushed record.
    pub fn latest(&self) -> Option<&FrameRecord> {
        if self.len == 0 {
            return None;
        }
        Some(&self.buf[(self.next + self.buf.len() - 1) % self.buf.len()])
    }

    /// Mutable access to the most recent record (scheduler annotation).
    pub fn latest_mut(&mut self) -> Option<&mut FrameRecord> {
        if self.len == 0 {
            return None;
        }
        let i = (self.next + self.buf.len() - 1) % self.buf.len();
        Some(&mut self.buf[i])
    }

    /// The last `n` records, oldest → newest.
    pub fn iter_recent(&self, n: usize) -> impl Iterator<Item = &FrameRecord> + '_ {
        let n = n.min(self.len);
        let cap = self.buf.len();
        let start = (self.next + cap - n) % cap;
        (0..n).map(move |i| &self.buf[(start + i) % cap])
    }

    /// Windowed aggregates over the last `window` frames (exact
    /// percentiles — sorts a scratch copy, allocates; snapshot path).
    pub fn summary(&self, window: usize) -> RingSummary {
        let n = window.min(self.len);
        if n == 0 {
            return RingSummary::default();
        }
        let mut step = Vec::with_capacity(n);
        let mut late = Vec::with_capacity(n);
        let mut queue = Vec::with_capacity(n);
        let mut out = RingSummary {
            frames: n,
            ..RingSummary::default()
        };
        let mut planned = 0usize;
        for r in self.iter_recent(n) {
            step.push(r.step_ns);
            late.push(r.lateness_ns);
            queue.push(r.queue_ns);
            if r.warped {
                out.warped_frames += 1;
            }
            if r.stalled {
                out.stalled += 1;
            }
            out.shards_loaded += r.shards_loaded as u64;
            out.pairs_mean += r.pairs as f64;
            out.warped_fraction_mean += r.warped_fraction as f64;
            out.masked_lane_fraction_mean += r.masked_lane_pm as f64 / 1000.0;
            if r.imbalance_pm > 0 {
                out.imbalance_mean += r.imbalance_pm as f64 / 1000.0;
                planned += 1;
            }
        }
        let nf = n as f64;
        out.pairs_mean /= nf;
        out.warped_fraction_mean /= nf;
        out.masked_lane_fraction_mean /= nf;
        if planned > 0 {
            out.imbalance_mean /= planned as f64;
        }
        step.sort_unstable();
        late.sort_unstable();
        queue.sort_unstable();
        let ms = |v: u64| v as f64 / 1e6;
        out.step_ms_mean = ms(step.iter().sum::<u64>() / n as u64);
        out.step_ms_p50 = ms(rank(&step, 0.50));
        out.step_ms_p95 = ms(rank(&step, 0.95));
        out.step_ms_p99 = ms(rank(&step, 0.99));
        out.lateness_ms_p50 = ms(rank(&late, 0.50));
        out.lateness_ms_p99 = ms(rank(&late, 0.99));
        out.queue_ms_p50 = ms(rank(&queue, 0.50));
        out.queue_ms_p99 = ms(rank(&queue, 0.99));
        out
    }
}

/// Nearest-rank percentile over a sorted slice.
fn rank(sorted: &[u64], q: f64) -> u64 {
    let n = sorted.len();
    if n == 0 {
        return 0;
    }
    let i = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
    sorted[i]
}

/// Aggregates over one ring window (milliseconds / plain ratios).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RingSummary {
    /// Frames in the window.
    pub frames: usize,
    pub warped_frames: usize,
    /// Paced steps that missed by more than their interval.
    pub stalled: usize,
    /// Shards loaded on frame critical paths in the window.
    pub shards_loaded: u64,
    pub step_ms_mean: f64,
    pub step_ms_p50: f64,
    pub step_ms_p95: f64,
    pub step_ms_p99: f64,
    pub lateness_ms_p50: f64,
    pub lateness_ms_p99: f64,
    pub queue_ms_p50: f64,
    pub queue_ms_p99: f64,
    /// Mean measured imbalance ratio over *planned* frames (0 if none).
    pub imbalance_mean: f64,
    /// Mean masked-lane fraction over the window.
    pub masked_lane_fraction_mean: f64,
    /// Mean warped-pixel fraction over the window.
    pub warped_fraction_mean: f64,
    /// Mean tile-splat pairs per frame.
    pub pairs_mean: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(i: u64, step_ns: u64) -> FrameRecord {
        FrameRecord {
            frame_idx: i,
            step_ns,
            warped: i % 5 != 0,
            ..FrameRecord::default()
        }
    }

    #[test]
    fn wraps_and_keeps_newest() {
        let mut ring = FrameRing::with_capacity(4);
        for i in 0..10u64 {
            ring.push(rec(i, i * 100));
        }
        assert_eq!(ring.len(), 4);
        assert_eq!(ring.total(), 10);
        assert_eq!(ring.latest().unwrap().frame_idx, 9);
        let idxs: Vec<u64> = ring.iter_recent(4).map(|r| r.frame_idx).collect();
        assert_eq!(idxs, vec![6, 7, 8, 9]);
        let idxs: Vec<u64> = ring.iter_recent(2).map(|r| r.frame_idx).collect();
        assert_eq!(idxs, vec![8, 9]);
    }

    #[test]
    fn summary_percentiles_are_exact_over_window() {
        let mut ring = FrameRing::with_capacity(128);
        for i in 1..=100u64 {
            ring.push(rec(i, i * 1_000_000)); // 1..=100 ms
        }
        let s = ring.summary(100);
        assert_eq!(s.frames, 100);
        assert_eq!(s.step_ms_p50, 50.0);
        assert_eq!(s.step_ms_p95, 95.0);
        assert_eq!(s.step_ms_p99, 99.0);
        assert!((s.step_ms_mean - 50.5).abs() < 0.51);
        // Window narrower than history: only the newest 10 count.
        let s10 = ring.summary(10);
        assert_eq!(s10.frames, 10);
        assert_eq!(s10.step_ms_p50, 95.0);
    }

    #[test]
    fn empty_ring_summary_is_zero() {
        let ring = FrameRing::with_capacity(8);
        assert!(ring.is_empty());
        assert_eq!(ring.summary(32), RingSummary::default());
        assert!(ring.latest().is_none());
    }

    #[test]
    fn annotation_reaches_latest() {
        let mut ring = FrameRing::with_capacity(8);
        ring.push(rec(0, 100));
        ring.push(rec(1, 200));
        let r = ring.latest_mut().unwrap();
        r.lateness_ns = 77;
        r.stalled = true;
        assert_eq!(ring.latest().unwrap().lateness_ns, 77);
        assert_eq!(ring.iter_recent(1).next().unwrap().frame_idx, 1);
    }
}
