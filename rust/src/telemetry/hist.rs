//! Fixed-bucket log-linear histograms: the percentile primitive under
//! every aggregated latency/imbalance signal in the telemetry layer.
//!
//! Layout: values `0..8` get unit-width buckets; every octave above that
//! is split into 8 linear sub-buckets, so relative quantization error is
//! bounded by 1/8 across the whole range. Values are clamped to
//! [`MAX_VALUE`] (~18 minutes in nanoseconds) — far beyond any per-frame
//! or per-shard latency this system produces. The bucket count is a
//! compile-time constant, so both variants preallocate everything:
//!
//! * [`Histogram`] — atomic buckets, `&self` recording with relaxed
//!   ordering only. Safe to share as a `static` and feed from the render
//!   hot path (one `fetch_add` per array slot, no locks, no allocation).
//! * [`LocalHistogram`] — plain-`u64` twin for single-owner accumulators
//!   ([`StageTimes`](crate::util::timer::StageTimes)); same bucket math,
//!   mergeable.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

/// Number of sub-buckets per octave (power-of-two value range).
pub const SUBS_PER_OCTAVE: usize = 8;

/// Largest recordable value; everything above clamps into the top bucket.
/// `2^40 - 1` ns is ≈ 18.3 minutes.
pub const MAX_VALUE: u64 = (1 << 40) - 1;

/// Total bucket count: 8 unit buckets + 8 sub-buckets for each octave
/// `[2^3, 2^4) .. [2^39, 2^40)`.
pub const NUM_BUCKETS: usize = SUBS_PER_OCTAVE + (40 - 3) * SUBS_PER_OCTAVE;

/// Map a value (already clamped to [`MAX_VALUE`]) to its bucket index.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < SUBS_PER_OCTAVE as u64 {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros() as u64; // >= 3
        let sub = (v >> (msb - 3)) - SUBS_PER_OCTAVE as u64;
        (SUBS_PER_OCTAVE as u64 + (msb - 3) * SUBS_PER_OCTAVE as u64 + sub) as usize
    }
}

/// Inclusive-lower / exclusive-upper value bounds of bucket `i`.
#[inline]
pub fn bucket_bounds(i: usize) -> (u64, u64) {
    if i < SUBS_PER_OCTAVE {
        (i as u64, i as u64 + 1)
    } else {
        let oct = (i - SUBS_PER_OCTAVE) / SUBS_PER_OCTAVE + 3;
        let sub = ((i - SUBS_PER_OCTAVE) % SUBS_PER_OCTAVE) as u64;
        let width = 1u64 << (oct - 3);
        let lo = (SUBS_PER_OCTAVE as u64 + sub) << (oct - 3);
        (lo, lo + width)
    }
}

/// Nearest-rank percentile with linear interpolation inside the bucket,
/// shared by both histogram variants. `counts(i)` yields bucket `i`'s
/// population; `total` is the overall count.
fn percentile_from(counts: impl Fn(usize) -> u64, total: u64, q: f64) -> u64 {
    if total == 0 {
        return 0;
    }
    let q = q.clamp(0.0, 1.0);
    let target = ((q * total as f64).ceil() as u64).clamp(1, total);
    let mut cum = 0u64;
    for i in 0..NUM_BUCKETS {
        let c = counts(i);
        if c == 0 {
            continue;
        }
        if cum + c >= target {
            let (lo, hi) = bucket_bounds(i);
            let frac = (target - cum) as f64 / c as f64;
            return lo + ((hi - lo) as f64 * frac) as u64;
        }
        cum += c;
    }
    MAX_VALUE
}

/// Point-in-time digest of a histogram (raw value units — the owning
/// field's name carries the unit, e.g. `frame_ns`, `imbalance_pm`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct HistSummary {
    pub count: u64,
    pub sum: u64,
    pub max: u64,
    pub mean: f64,
    pub p50: u64,
    pub p95: u64,
    pub p99: u64,
}

/// Lock-free shared histogram: relaxed atomic buckets, `&self` recording.
pub struct Histogram {
    buckets: [AtomicU64; NUM_BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Histogram {
    pub const fn new() -> Histogram {
        Histogram {
            buckets: [const { AtomicU64::new(0) }; NUM_BUCKETS],
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    /// Record one observation. Allocation-free, lock-free: four relaxed
    /// `fetch_add`s and one relaxed `fetch_max`.
    #[inline]
    pub fn record(&self, v: u64) {
        let v = v.min(MAX_VALUE);
        self.buckets[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Record a duration in nanoseconds.
    #[inline]
    pub fn record_duration(&self, d: Duration) {
        self.record(d.as_nanos().min(MAX_VALUE as u128) as u64);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Sum of recorded values (saturating).
    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Largest recorded value.
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum() as f64 / n as f64
        }
    }

    /// Approximate percentile (`q` in `[0, 1]`), ≤ 1/8 relative error.
    pub fn percentile(&self, q: f64) -> u64 {
        percentile_from(|i| self.buckets[i].load(Ordering::Relaxed), self.count(), q)
    }

    /// One-shot digest: count/sum/max/mean + p50/p95/p99.
    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count(),
            sum: self.sum(),
            max: self.max(),
            mean: self.mean(),
            p50: self.percentile(0.50),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
        }
    }
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

/// Single-owner histogram: identical bucket math, no atomics, mergeable.
#[derive(Clone)]
pub struct LocalHistogram {
    buckets: [u64; NUM_BUCKETS],
    count: u64,
    sum: u64,
    max: u64,
}

impl LocalHistogram {
    pub const fn new() -> LocalHistogram {
        LocalHistogram {
            buckets: [0; NUM_BUCKETS],
            count: 0,
            sum: 0,
            max: 0,
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        let v = v.min(MAX_VALUE);
        self.buckets[bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v;
        self.max = self.max.max(v);
    }

    #[inline]
    pub fn record_duration(&mut self, d: Duration) {
        self.record(d.as_nanos().min(MAX_VALUE as u128) as u64);
    }

    /// Fold another histogram's buckets into this one.
    pub fn merge(&mut self, other: &LocalHistogram) {
        for (b, o) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *b += *o;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }

    /// Observations recorded.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of recorded values (saturating).
    pub fn sum(&self) -> u64 {
        self.sum
    }

    /// Largest recorded value.
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Mean of recorded values (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }

    /// Approximate percentile (`q` in `[0, 1]`), ≤ 1/8 relative error.
    pub fn percentile(&self, q: f64) -> u64 {
        percentile_from(|i| self.buckets[i], self.count, q)
    }

    /// One-shot digest: count/sum/max/mean + p50/p95/p99.
    pub fn summary(&self) -> HistSummary {
        HistSummary {
            count: self.count,
            sum: self.sum,
            max: self.max,
            mean: self.mean(),
            p50: self.percentile(0.50),
            p95: self.percentile(0.95),
            p99: self.percentile(0.99),
        }
    }
}

impl Default for LocalHistogram {
    fn default() -> LocalHistogram {
        LocalHistogram::new()
    }
}

impl std::fmt::Debug for LocalHistogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LocalHistogram")
            .field("count", &self.count)
            .field("sum", &self.sum)
            .field("max", &self.max)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_is_monotone_and_in_range() {
        let mut prev = 0usize;
        let mut v = 0u64;
        while v <= MAX_VALUE {
            let i = bucket_index(v);
            assert!(i < NUM_BUCKETS, "index {i} out of range for {v}");
            assert!(i >= prev, "bucket index regressed at {v}");
            prev = i;
            v = (v * 2).max(v + 1); // sample every octave boundary ±
        }
        assert_eq!(bucket_index(MAX_VALUE), NUM_BUCKETS - 1);
    }

    #[test]
    fn bucket_bounds_partition_the_range() {
        let mut expected_lo = 0u64;
        for i in 0..NUM_BUCKETS {
            let (lo, hi) = bucket_bounds(i);
            assert_eq!(lo, expected_lo, "gap before bucket {i}");
            assert!(hi > lo);
            // Every value in [lo, hi) maps back to bucket i.
            assert_eq!(bucket_index(lo), i);
            assert_eq!(bucket_index(hi - 1), i);
            expected_lo = hi;
        }
        assert_eq!(expected_lo, MAX_VALUE + 1);
    }

    #[test]
    fn percentiles_are_within_relative_error() {
        let h = Histogram::new();
        for v in 1..=10_000u64 {
            h.record(v);
        }
        for (q, exact) in [(0.50, 5_000.0), (0.95, 9_500.0), (0.99, 9_900.0)] {
            let got = h.percentile(q) as f64;
            let rel = (got - exact).abs() / exact;
            assert!(rel <= 0.125, "p{q}: got {got}, exact {exact}, rel {rel}");
        }
        assert_eq!(h.count(), 10_000);
        assert_eq!(h.max(), 10_000);
        assert!((h.mean() - 5_000.5).abs() < 1.0);
    }

    #[test]
    fn clamps_at_max_value() {
        let h = Histogram::new();
        h.record(u64::MAX);
        assert_eq!(h.max(), MAX_VALUE);
        assert_eq!(h.percentile(1.0), MAX_VALUE);
    }

    #[test]
    fn local_merge_matches_combined() {
        let mut a = LocalHistogram::new();
        let mut b = LocalHistogram::new();
        let mut c = LocalHistogram::new();
        for v in 0..1_000u64 {
            if v % 2 == 0 {
                a.record(v * 3);
            } else {
                b.record(v * 3);
            }
            c.record(v * 3);
        }
        a.merge(&b);
        assert_eq!(a.count(), c.count());
        assert_eq!(a.sum(), c.sum());
        assert_eq!(a.max(), c.max());
        assert_eq!(a.percentile(0.5), c.percentile(0.5));
        assert_eq!(a.percentile(0.99), c.percentile(0.99));
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = Histogram::new();
        let s = h.summary();
        assert_eq!(s, HistSummary::default());
    }
}
