//! Process-wide metrics hub: one static struct of atomic counters and
//! [`Histogram`]s that every layer records into as frames commit.
//!
//! The hub is intentionally a *fixed* set of fields rather than a string
//! registry: the hot paths that feed it (session step, scheduler commit,
//! shard load) must stay allocation-free and lock-free, and a static
//! struct of atomics is the cheapest thing that is. Aggregation across
//! sessions/scenes happens read-side in
//! [`StreamServer::telemetry_snapshot`](crate::serve::StreamServer::telemetry_snapshot)
//! via [`NodeTelemetry::capture`](crate::telemetry::NodeTelemetry::capture).
//!
//! Units are encoded in field names: `_ns` nanoseconds, `_pm` permille
//! (ratios × 1000, so imbalance 1.25 records as 1250).

use super::hist::Histogram;
use std::sync::atomic::{AtomicU64, Ordering};

/// All cross-layer aggregated signals. Fields are public: call sites
/// record straight into the histogram/counter they own.
pub struct MetricsHub {
    /// Wall-clock of `StreamSession::step` (full + warped frames).
    pub frame_ns: Histogram,
    /// Scheduler lateness of paced steps (finish − deadline).
    pub lateness_ns: Histogram,
    /// Queue wait of paced steps (start − deadline).
    pub queue_wait_ns: Histogram,
    /// Measured plan imbalance (max/mean partition time, permille) of
    /// planned passes.
    pub imbalance_pm: Histogram,
    /// Masked-lane waste of SIMD passes (masked/total lanes, permille).
    pub masked_lane_pm: Histogram,
    /// Per-shard load latency, memory-backed stores.
    pub load_ns_mem: Histogram,
    /// Per-shard load latency, file-backed stores.
    pub load_ns_file: Histogram,
    /// Total frames stepped.
    pub frames: AtomicU64,
    /// Dense (window-boundary) frames.
    pub full_frames: AtomicU64,
    /// Warped (TWSR / pixel) frames.
    pub warped_frames: AtomicU64,
    /// Paced steps whose lateness exceeded their interval.
    pub stalled_steps: AtomicU64,
    /// Individual shard loads (frame-critical + prefetch).
    pub shard_loads: AtomicU64,
    /// Shards evicted by the cross-scene residency governor.
    pub governor_evictions: AtomicU64,
    /// QoS ladder degradations (quality stepped down one rung).
    pub qos_level_downs: AtomicU64,
    /// QoS ladder promotions (quality stepped back up one rung).
    pub qos_level_ups: AtomicU64,
    /// Queued poses shed by the paced scheduler from stalled sessions.
    pub qos_shed_frames: AtomicU64,
    /// Sessions refused by the server's admission policy.
    pub qos_rejected_sessions: AtomicU64,
    /// Sessions admitted pre-degraded at the bottom ladder rung.
    pub qos_downtiered_sessions: AtomicU64,
    /// Per paced step: headroom left in the pacing interval, permille
    /// (0 = the step overran its interval). QoS-enabled sessions only.
    pub qos_headroom_pm: Histogram,
    /// Masked passes served incrementally from the temporal plan cache.
    pub plan_cache_hits: AtomicU64,
    /// Masked passes that fell back to a full re-plan (cold cache or
    /// pose drift beyond the guard-band bound).
    pub plan_cache_fallbacks: AtomicU64,
    /// Per plan-cache hit: fraction of active tiles re-binned, permille.
    pub plan_rebin_pm: Histogram,
    /// Quality probes scored (dense reference rendered + compared).
    pub probe_frames: AtomicU64,
    /// Probes skipped because the pool had no idle capacity.
    pub probe_skipped: AtomicU64,
    /// Probe PSNR of served vs dense-reference frames, centi-dB
    /// (34.17 dB records as 3417), attributed to the QoS rung the
    /// session occupied when the frame was served.
    pub probe_psnr_cdb: [Histogram; QUALITY_RUNGS],
    /// Probe SSIM, permille, per QoS rung.
    pub probe_ssim_pm: [Histogram; QUALITY_RUNGS],
}

/// Number of QoS ladder rungs the probe histograms attribute quality
/// to. Must equal `serve::qos::LADDER.len()` — asserted by a unit test
/// on the qos side (the hub cannot depend on `serve`).
pub const QUALITY_RUNGS: usize = 4;

impl MetricsHub {
    pub const fn new() -> MetricsHub {
        MetricsHub {
            frame_ns: Histogram::new(),
            lateness_ns: Histogram::new(),
            queue_wait_ns: Histogram::new(),
            imbalance_pm: Histogram::new(),
            masked_lane_pm: Histogram::new(),
            load_ns_mem: Histogram::new(),
            load_ns_file: Histogram::new(),
            frames: AtomicU64::new(0),
            full_frames: AtomicU64::new(0),
            warped_frames: AtomicU64::new(0),
            stalled_steps: AtomicU64::new(0),
            shard_loads: AtomicU64::new(0),
            governor_evictions: AtomicU64::new(0),
            qos_level_downs: AtomicU64::new(0),
            qos_level_ups: AtomicU64::new(0),
            qos_shed_frames: AtomicU64::new(0),
            qos_rejected_sessions: AtomicU64::new(0),
            qos_downtiered_sessions: AtomicU64::new(0),
            qos_headroom_pm: Histogram::new(),
            plan_cache_hits: AtomicU64::new(0),
            plan_cache_fallbacks: AtomicU64::new(0),
            plan_rebin_pm: Histogram::new(),
            probe_frames: AtomicU64::new(0),
            probe_skipped: AtomicU64::new(0),
            probe_psnr_cdb: [const { Histogram::new() }; QUALITY_RUNGS],
            probe_ssim_pm: [const { Histogram::new() }; QUALITY_RUNGS],
        }
    }

    /// Record one scored quality probe, attributed to QoS rung `level`.
    #[inline]
    pub fn record_probe(&self, level: u8, psnr_cdb: u64, ssim_pm: u64) {
        let rung = (level as usize).min(QUALITY_RUNGS - 1);
        self.probe_frames.fetch_add(1, Ordering::Relaxed);
        self.probe_psnr_cdb[rung].record(psnr_cdb);
        self.probe_ssim_pm[rung].record(ssim_pm);
    }

    /// Record one committed frame (every `StreamSession::step`).
    #[inline]
    pub fn record_frame(&self, full: bool, step_ns: u64) {
        self.frames.fetch_add(1, Ordering::Relaxed);
        if full {
            self.full_frames.fetch_add(1, Ordering::Relaxed);
        } else {
            self.warped_frames.fetch_add(1, Ordering::Relaxed);
        }
        self.frame_ns.record(step_ns);
    }

    /// Record one paced scheduler commit.
    #[inline]
    pub fn record_sched(&self, lateness_ns: u64, queue_ns: u64, stalled: bool) {
        self.lateness_ns.record(lateness_ns);
        self.queue_wait_ns.record(queue_ns);
        if stalled {
            self.stalled_steps.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Record one shard load (`file` selects the store-kind histogram).
    #[inline]
    pub fn record_shard_load(&self, file: bool, load_ns: u64) {
        self.shard_loads.fetch_add(1, Ordering::Relaxed);
        if file {
            self.load_ns_file.record(load_ns);
        } else {
            self.load_ns_mem.record(load_ns);
        }
    }
}

impl Default for MetricsHub {
    fn default() -> MetricsHub {
        MetricsHub::new()
    }
}

static HUB: MetricsHub = MetricsHub::new();

/// The process-wide hub. Counters are lifetime totals for this process;
/// read-side consumers take deltas if they need windows.
#[inline]
pub fn hub() -> &'static MetricsHub {
    &HUB
}

#[cfg(test)]
mod tests {
    use super::*;

    // The hub is process-global, so tests assert monotonic deltas only.
    #[test]
    fn frame_and_sched_records_accumulate() {
        let h = MetricsHub::new();
        h.record_frame(true, 1_000_000);
        h.record_frame(false, 500_000);
        h.record_sched(10_000, 2_000, true);
        h.record_shard_load(false, 30_000);
        h.record_shard_load(true, 400_000);
        assert_eq!(h.frames.load(Ordering::Relaxed), 2);
        assert_eq!(h.full_frames.load(Ordering::Relaxed), 1);
        assert_eq!(h.warped_frames.load(Ordering::Relaxed), 1);
        assert_eq!(h.stalled_steps.load(Ordering::Relaxed), 1);
        assert_eq!(h.shard_loads.load(Ordering::Relaxed), 2);
        assert_eq!(h.frame_ns.count(), 2);
        assert_eq!(h.lateness_ns.count(), 1);
        assert_eq!(h.load_ns_mem.count(), 1);
        assert_eq!(h.load_ns_file.count(), 1);
        assert!(h.frame_ns.percentile(0.99) >= 900_000);
    }

    #[test]
    fn global_hub_is_reachable() {
        let before = hub().frames.load(Ordering::Relaxed);
        hub().record_frame(true, 1);
        assert!(hub().frames.load(Ordering::Relaxed) > before);
    }
}
