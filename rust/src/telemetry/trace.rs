//! Chrome trace-event span tracer (`LSG_TRACE=<path>`), loadable in
//! Perfetto / `chrome://tracing`.
//!
//! Disabled (the default) it costs one relaxed atomic load per span —
//! no `Instant::now`, no allocation, no lock — so it can sit on the
//! render hot path permanently. Set `LSG_TRACE=out.json` and every
//! scoped [`span`] records a complete (`"ph":"X"`) event into a global
//! buffer; [`flush`] writes the whole buffer as a well-formed JSON
//! object.
//!
//! Since PR 10 the tracer is **runtime-toggleable**: [`start`] begins a
//! fresh recording to a new path and [`stop`] flushes and disarms it —
//! this is what the admin endpoint's `POST /trace/start|stop` drives
//! (`docs/OBSERVABILITY.md`). The `LSG_TRACE` environment variable is
//! now only the *boot-time default* (consulted once, at the first span
//! or toggle), not a process-lifetime latch; the off-path cost is still
//! a single relaxed load.
//!
//! Conventions: `pid` is always 1; real threads get dense `tid`s in
//! creation order; retrospective scheduler events ride per-session
//! virtual tracks at [`SCHED_TRACK_BASE`]` + session` so queue-wait
//! intervals (which span worker handoffs) never break same-thread span
//! nesting. Timestamps are microseconds (fractional, ns precision) from
//! a process-local epoch.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU32, AtomicU8, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Virtual `tid` base for per-session scheduler tracks.
pub const SCHED_TRACK_BASE: u32 = 1_000_000;

const UNINIT: u8 = 0;
const OFF: u8 = 1;
const ON: u8 = 2;

static STATE: AtomicU8 = AtomicU8::new(UNINIT);
static PATH: Mutex<Option<String>> = Mutex::new(None);
static EPOCH: OnceLock<Instant> = OnceLock::new();
static EVENTS: Mutex<Vec<TraceEvent>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU32 = AtomicU32::new(1);

thread_local! {
    static TID: u32 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

#[derive(Clone, Copy)]
struct TraceEvent {
    name: &'static str,
    tid: u32,
    ts_ns: u64,
    dur_ns: u64,
}

/// Whether tracing is active right now. The boot-time default comes
/// from `LSG_TRACE` (consulted on the first call); [`start`]/[`stop`]
/// flip it at runtime.
#[inline]
pub fn enabled() -> bool {
    match STATE.load(Ordering::Relaxed) {
        ON => true,
        OFF => false,
        _ => init(),
    }
}

#[cold]
fn init() -> bool {
    let on = match std::env::var("LSG_TRACE") {
        Ok(p) if !p.is_empty() => {
            *PATH.lock().unwrap() = Some(p);
            true
        }
        _ => false,
    };
    let _ = EPOCH.set(Instant::now());
    STATE.store(if on { ON } else { OFF }, Ordering::Relaxed);
    on
}

fn epoch() -> Instant {
    *EPOCH.get_or_init(Instant::now)
}

fn push_event(name: &'static str, tid: u32, start: Instant, end: Instant) {
    let ts_ns = start.saturating_duration_since(epoch()).as_nanos() as u64;
    let dur_ns = end.saturating_duration_since(start).as_nanos() as u64;
    if let Ok(mut events) = EVENTS.lock() {
        events.push(TraceEvent {
            name,
            tid,
            ts_ns,
            dur_ns,
        });
    }
}

/// Begin (or retarget) a recording: clears the event buffer, points the
/// tracer at `path`, and enables span capture. Safe to call whether or
/// not tracing was already on; the env default is latched first so a
/// later [`stop`] returns to OFF, not to the env state.
pub fn start(path: &str) {
    enabled(); // latch the env default + epoch exactly once
    if let Ok(mut events) = EVENTS.lock() {
        events.clear();
    }
    *PATH.lock().unwrap() = Some(path.to_string());
    STATE.store(ON, Ordering::Relaxed);
}

/// Stop recording: flush buffered events to the active path, then
/// disable span capture. Returns the path written, or `None` when
/// tracing was not on (or the write failed). The buffer is kept, so a
/// later [`start`]-less [`flush`] call sees nothing new but loses
/// nothing either.
pub fn stop() -> Option<PathBuf> {
    if !enabled() {
        return None;
    }
    let written = flush();
    STATE.store(OFF, Ordering::Relaxed);
    written
}

/// Scoped span guard: records a complete event on drop when tracing is
/// enabled, does nothing otherwise.
#[must_use]
pub struct Span {
    name: &'static str,
    start: Option<Instant>,
}

/// Open a span over the enclosing scope. With tracing disabled this is
/// one relaxed atomic load and an inert guard.
#[inline]
pub fn span(name: &'static str) -> Span {
    Span {
        name,
        start: if enabled() { Some(Instant::now()) } else { None },
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if let Some(start) = self.start {
            let end = Instant::now();
            TID.with(|t| push_event(self.name, *t, start, end));
        }
    }
}

/// Record a retrospective complete event on the calling thread's track
/// (for intervals measured before the tracer could scope them).
pub fn complete(name: &'static str, start: Instant, end: Instant) {
    if enabled() {
        let start = start.min(end);
        TID.with(|t| push_event(name, *t, start, end));
    }
}

/// Record a retrospective complete event on an explicit virtual track
/// (e.g. [`SCHED_TRACK_BASE`]` + session` for queue-wait intervals that
/// span worker-thread handoffs).
pub fn complete_on(name: &'static str, track: u32, start: Instant, end: Instant) {
    if enabled() {
        let start = start.min(end);
        push_event(name, track, start, end);
    }
}

/// Write every event recorded so far to the active trace path as a
/// Chrome trace-event JSON object. Keeps the buffer, so a later flush
/// rewrites a strictly larger file — call at process exit (benches,
/// examples) or after the workload of interest. Returns the path
/// written, or `None` when tracing is disabled or the write failed.
pub fn flush() -> Option<PathBuf> {
    use std::fmt::Write as _;
    if !enabled() {
        return None;
    }
    let path = PATH.lock().ok()?.clone()?;
    let events: Vec<TraceEvent> = EVENTS.lock().ok()?.clone();
    let mut out = String::with_capacity(events.len() * 96 + 64);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    for (i, e) in events.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let _ = write!(
            out,
            "{{\"name\":\"{}\",\"cat\":\"lsg\",\"ph\":\"X\",\"pid\":1,\"tid\":{},\
             \"ts\":{}.{:03},\"dur\":{}.{:03}}}",
            e.name,
            e.tid,
            e.ts_ns / 1_000,
            e.ts_ns % 1_000,
            e.dur_ns / 1_000,
            e.dur_ns % 1_000,
        );
    }
    out.push_str("]}");
    std::fs::write(&path, out).ok()?;
    Some(PathBuf::from(path))
}

/// Events currently buffered (0 when disabled). Test/diagnostic hook.
pub fn buffered_events() -> usize {
    EVENTS.lock().map(|e| e.len()).unwrap_or(0)
}
