//! Row-major 3×3 and 4×4 f32 matrices: rotation/covariance algebra for
//! Gaussian projection and camera transforms.

use super::vec::{Vec3, Vec4};
use std::ops::Mul;

/// Row-major 3×3 matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat3 {
    pub m: [[f32; 3]; 3],
}

/// Row-major 4×4 matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat4 {
    pub m: [[f32; 4]; 4],
}

impl Mat3 {
    pub const IDENTITY: Mat3 = Mat3 {
        m: [[1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [0.0, 0.0, 1.0]],
    };

    pub fn from_rows(r0: Vec3, r1: Vec3, r2: Vec3) -> Mat3 {
        Mat3 {
            m: [
                [r0.x, r0.y, r0.z],
                [r1.x, r1.y, r1.z],
                [r2.x, r2.y, r2.z],
            ],
        }
    }

    pub fn from_cols(c0: Vec3, c1: Vec3, c2: Vec3) -> Mat3 {
        Mat3::from_rows(c0, c1, c2).transpose()
    }

    pub fn diag(d: Vec3) -> Mat3 {
        Mat3 {
            m: [[d.x, 0.0, 0.0], [0.0, d.y, 0.0], [0.0, 0.0, d.z]],
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> Vec3 {
        Vec3::new(self.m[i][0], self.m[i][1], self.m[i][2])
    }

    #[inline]
    pub fn col(&self, j: usize) -> Vec3 {
        Vec3::new(self.m[0][j], self.m[1][j], self.m[2][j])
    }

    pub fn transpose(&self) -> Mat3 {
        Mat3::from_rows(self.col(0), self.col(1), self.col(2))
    }

    pub fn det(&self) -> f32 {
        let m = &self.m;
        m[0][0] * (m[1][1] * m[2][2] - m[1][2] * m[2][1])
            - m[0][1] * (m[1][0] * m[2][2] - m[1][2] * m[2][0])
            + m[0][2] * (m[1][0] * m[2][1] - m[1][1] * m[2][0])
    }

    /// Inverse; returns None when |det| is tiny.
    pub fn inverse(&self) -> Option<Mat3> {
        let d = self.det();
        if d.abs() < 1e-12 {
            return None;
        }
        let inv_d = 1.0 / d;
        let m = &self.m;
        let mut out = [[0.0f32; 3]; 3];
        out[0][0] = (m[1][1] * m[2][2] - m[1][2] * m[2][1]) * inv_d;
        out[0][1] = (m[0][2] * m[2][1] - m[0][1] * m[2][2]) * inv_d;
        out[0][2] = (m[0][1] * m[1][2] - m[0][2] * m[1][1]) * inv_d;
        out[1][0] = (m[1][2] * m[2][0] - m[1][0] * m[2][2]) * inv_d;
        out[1][1] = (m[0][0] * m[2][2] - m[0][2] * m[2][0]) * inv_d;
        out[1][2] = (m[0][2] * m[1][0] - m[0][0] * m[1][2]) * inv_d;
        out[2][0] = (m[1][0] * m[2][1] - m[1][1] * m[2][0]) * inv_d;
        out[2][1] = (m[0][1] * m[2][0] - m[0][0] * m[2][1]) * inv_d;
        out[2][2] = (m[0][0] * m[1][1] - m[0][1] * m[1][0]) * inv_d;
        Some(Mat3 { m: out })
    }
}

impl Mul<Vec3> for Mat3 {
    type Output = Vec3;
    #[inline]
    fn mul(self, v: Vec3) -> Vec3 {
        Vec3::new(self.row(0).dot(v), self.row(1).dot(v), self.row(2).dot(v))
    }
}

impl Mul<Mat3> for Mat3 {
    type Output = Mat3;
    fn mul(self, o: Mat3) -> Mat3 {
        let mut out = [[0.0f32; 3]; 3];
        for (i, row) in out.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                *cell = self.row(i).dot(o.col(j));
            }
        }
        Mat3 { m: out }
    }
}

impl Mat4 {
    pub const IDENTITY: Mat4 = Mat4 {
        m: [
            [1.0, 0.0, 0.0, 0.0],
            [0.0, 1.0, 0.0, 0.0],
            [0.0, 0.0, 1.0, 0.0],
            [0.0, 0.0, 0.0, 1.0],
        ],
    };

    /// Rigid transform from rotation + translation: x ↦ R·x + t.
    pub fn from_rt(r: Mat3, t: Vec3) -> Mat4 {
        Mat4 {
            m: [
                [r.m[0][0], r.m[0][1], r.m[0][2], t.x],
                [r.m[1][0], r.m[1][1], r.m[1][2], t.y],
                [r.m[2][0], r.m[2][1], r.m[2][2], t.z],
                [0.0, 0.0, 0.0, 1.0],
            ],
        }
    }

    pub fn rotation(&self) -> Mat3 {
        Mat3 {
            m: [
                [self.m[0][0], self.m[0][1], self.m[0][2]],
                [self.m[1][0], self.m[1][1], self.m[1][2]],
                [self.m[2][0], self.m[2][1], self.m[2][2]],
            ],
        }
    }

    pub fn translation(&self) -> Vec3 {
        Vec3::new(self.m[0][3], self.m[1][3], self.m[2][3])
    }

    #[inline]
    pub fn row(&self, i: usize) -> Vec4 {
        Vec4::new(self.m[i][0], self.m[i][1], self.m[i][2], self.m[i][3])
    }

    /// Transform a point (w = 1).
    #[inline]
    pub fn transform_point(&self, p: Vec3) -> Vec3 {
        let v = p.extend(1.0);
        Vec3::new(self.row(0).dot(v), self.row(1).dot(v), self.row(2).dot(v))
    }

    /// Transform a direction (w = 0).
    #[inline]
    pub fn transform_dir(&self, d: Vec3) -> Vec3 {
        let v = d.extend(0.0);
        Vec3::new(self.row(0).dot(v), self.row(1).dot(v), self.row(2).dot(v))
    }

    /// Inverse of a rigid transform (R orthonormal): [Rᵀ | -Rᵀt].
    pub fn rigid_inverse(&self) -> Mat4 {
        let rt = self.rotation().transpose();
        let t = self.translation();
        Mat4::from_rt(rt, -(rt * t))
    }
}

impl Mul<Mat4> for Mat4 {
    type Output = Mat4;
    fn mul(self, o: Mat4) -> Mat4 {
        let mut out = [[0.0f32; 4]; 4];
        for (i, row) in out.iter_mut().enumerate() {
            for (j, cell) in row.iter_mut().enumerate() {
                *cell = (0..4).map(|k| self.m[i][k] * o.m[k][j]).sum();
            }
        }
        Mat4 { m: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::quat::Quat;

    fn mat3_close(a: Mat3, b: Mat3, eps: f32) -> bool {
        (0..3).all(|i| (0..3).all(|j| (a.m[i][j] - b.m[i][j]).abs() < eps))
    }

    #[test]
    fn identity_mul() {
        let v = Vec3::new(1.0, -2.0, 3.0);
        assert_eq!(Mat3::IDENTITY * v, v);
        let m = Mat3::from_rows(
            Vec3::new(1.0, 2.0, 3.0),
            Vec3::new(0.0, 1.0, 4.0),
            Vec3::new(5.0, 6.0, 0.0),
        );
        assert!(mat3_close(m * Mat3::IDENTITY, m, 1e-6));
    }

    #[test]
    fn inverse_roundtrip() {
        let m = Mat3::from_rows(
            Vec3::new(1.0, 2.0, 3.0),
            Vec3::new(0.0, 1.0, 4.0),
            Vec3::new(5.0, 6.0, 0.0),
        );
        let inv = m.inverse().unwrap();
        assert!(mat3_close(m * inv, Mat3::IDENTITY, 1e-4));
    }

    #[test]
    fn singular_has_no_inverse() {
        let m = Mat3::from_rows(Vec3::X, Vec3::X, Vec3::Z);
        assert!(m.inverse().is_none());
    }

    #[test]
    fn det_of_diag() {
        assert_eq!(Mat3::diag(Vec3::new(2.0, 3.0, 4.0)).det(), 24.0);
    }

    #[test]
    fn transpose_involution() {
        let m = Mat3::from_rows(
            Vec3::new(1.0, 2.0, 3.0),
            Vec3::new(4.0, 5.0, 6.0),
            Vec3::new(7.0, 8.0, 9.0),
        );
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn mat4_point_vs_dir() {
        let t = Mat4::from_rt(Mat3::IDENTITY, Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(t.transform_point(Vec3::ZERO), Vec3::new(1.0, 2.0, 3.0));
        assert_eq!(t.transform_dir(Vec3::X), Vec3::X);
    }

    #[test]
    fn rigid_inverse_roundtrip() {
        let r = Quat::from_axis_angle(Vec3::new(1.0, 2.0, 0.5).normalized(), 0.7).to_mat3();
        let t = Mat4::from_rt(r, Vec3::new(3.0, -1.0, 2.0));
        let p = Vec3::new(0.5, 0.25, -4.0);
        let back = t.rigid_inverse().transform_point(t.transform_point(p));
        assert!((back - p).norm() < 1e-5);
    }

    #[test]
    fn mat4_compose_matches_sequential() {
        let r = Quat::from_axis_angle(Vec3::Z, 0.3).to_mat3();
        let a = Mat4::from_rt(r, Vec3::new(1.0, 0.0, 0.0));
        let b = Mat4::from_rt(Mat3::IDENTITY, Vec3::new(0.0, 2.0, 0.0));
        let p = Vec3::new(1.0, 1.0, 1.0);
        let seq = a.transform_point(b.transform_point(p));
        let composed = (a * b).transform_point(p);
        assert!((seq - composed).norm() < 1e-5);
    }
}
