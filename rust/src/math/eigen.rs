//! Closed-form eigendecomposition of symmetric 2×2 matrices — the core of
//! every intersection test: the projected Gaussian's 2D covariance
//! Σ' = [[a, b], [b, c]] has eigenvalues λ₁ ≥ λ₂ defining the splat's
//! semi-major/minor axes (paper Sec. IV-C, Eq. 4).

use super::vec::Vec2;

/// Eigenvalues (λ₁ ≥ λ₂) and the unit eigenvector of λ₁.
#[derive(Clone, Copy, Debug)]
pub struct Eigen2 {
    pub l1: f32,
    pub l2: f32,
    /// Unit eigenvector of λ₁ (major-axis direction).
    pub v1: Vec2,
}

/// Eigenvalues of [[a, b], [b, c]], λ₁ ≥ λ₂. Uses the stable midpoint ±
/// radius form; clamps the discriminant at zero against rounding.
#[inline]
pub fn eigvals2x2(a: f32, b: f32, c: f32) -> (f32, f32) {
    let mid = 0.5 * (a + c);
    let half_diff = 0.5 * (a - c);
    let radius = (half_diff * half_diff + b * b).max(0.0).sqrt();
    (mid + radius, mid - radius)
}

/// Full decomposition including the major-axis direction.
pub fn eigen2x2(a: f32, b: f32, c: f32) -> Eigen2 {
    let (l1, l2) = eigvals2x2(a, b, c);
    // Eigenvector for l1: (b, l1 - a) or (l1 - c, b); pick the better
    // conditioned one.
    let v = if b.abs() > 1e-12 {
        if (l1 - a).abs() > (l1 - c).abs() {
            Vec2::new(b, l1 - a)
        } else {
            Vec2::new(l1 - c, b)
        }
    } else if a >= c {
        Vec2::new(1.0, 0.0)
    } else {
        Vec2::new(0.0, 1.0)
    };
    Eigen2 {
        l1,
        l2,
        v1: v.normalized(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn diagonal_matrix() {
        let (l1, l2) = eigvals2x2(3.0, 0.0, 1.0);
        assert_eq!((l1, l2), (3.0, 1.0));
        let e = eigen2x2(3.0, 0.0, 1.0);
        assert!((e.v1.x.abs() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn swapped_diagonal() {
        let e = eigen2x2(1.0, 0.0, 3.0);
        assert_eq!((e.l1, e.l2), (3.0, 1.0));
        assert!((e.v1.y.abs() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn known_offdiagonal() {
        // [[2,1],[1,2]] has eigenvalues 3 and 1, v1 = (1,1)/sqrt2.
        let e = eigen2x2(2.0, 1.0, 2.0);
        assert!((e.l1 - 3.0).abs() < 1e-5);
        assert!((e.l2 - 1.0).abs() < 1e-5);
        assert!((e.v1.x.abs() - e.v1.y.abs()).abs() < 1e-5);
    }

    #[test]
    fn eigenvector_property_holds() {
        check("A v1 = l1 v1 for random PSD matrices", 512, |rng| {
            // Build a random symmetric PSD matrix R D Rᵀ.
            let theta = rng.range(0.0, std::f32::consts::TAU);
            let (s, c) = theta.sin_cos();
            let d1 = rng.range(0.01, 100.0);
            let d2 = rng.range(0.01, 100.0);
            let a = c * c * d1 + s * s * d2;
            let b = s * c * (d1 - d2);
            let cc = s * s * d1 + c * c * d2;
            let e = eigen2x2(a, b, cc);
            // λ₁ must equal max(d1,d2) and the eigen equation must hold.
            assert!((e.l1 - d1.max(d2)).abs() < 1e-2 * d1.max(d2).max(1.0));
            let av = Vec2::new(a * e.v1.x + b * e.v1.y, b * e.v1.x + cc * e.v1.y);
            let lv = e.v1 * e.l1;
            assert!(
                (av - lv).norm() < 1e-2 * e.l1.max(1.0),
                "residual {:?}",
                (av - lv).norm()
            );
        });
    }

    #[test]
    fn trace_and_det_invariants() {
        check("l1+l2 = trace, l1*l2 = det", 512, |rng| {
            let a = rng.range(0.0, 50.0);
            let c = rng.range(0.0, 50.0);
            let b = rng.range(-10.0, 10.0);
            let (l1, l2) = eigvals2x2(a, b, c);
            assert!((l1 + l2 - (a + c)).abs() < 1e-3 * (a + c).abs().max(1.0));
            assert!((l1 * l2 - (a * c - b * b)).abs() < 2e-2 * (a * c).abs().max(1.0));
            assert!(l1 >= l2);
        });
    }
}
