//! 2/3/4-component f32 vectors with the handful of operations the renderer
//! needs. Plain structs + operators; everything `#[inline]` for the hot path.

use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec2 {
    pub x: f32,
    pub y: f32,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec3 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Vec4 {
    pub x: f32,
    pub y: f32,
    pub z: f32,
    pub w: f32,
}

impl Vec2 {
    pub const ZERO: Vec2 = Vec2 { x: 0.0, y: 0.0 };

    #[inline]
    pub fn new(x: f32, y: f32) -> Vec2 {
        Vec2 { x, y }
    }

    #[inline]
    pub fn dot(self, o: Vec2) -> f32 {
        self.x * o.x + self.y * o.y
    }

    #[inline]
    pub fn norm(self) -> f32 {
        self.dot(self).sqrt()
    }

    #[inline]
    pub fn normalized(self) -> Vec2 {
        let n = self.norm();
        if n > 0.0 {
            self / n
        } else {
            Vec2::ZERO
        }
    }

    /// Perpendicular (rotated +90°).
    #[inline]
    pub fn perp(self) -> Vec2 {
        Vec2::new(-self.y, self.x)
    }
}

impl Vec3 {
    pub const ZERO: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 0.0 };
    pub const ONE: Vec3 = Vec3 { x: 1.0, y: 1.0, z: 1.0 };
    pub const X: Vec3 = Vec3 { x: 1.0, y: 0.0, z: 0.0 };
    pub const Y: Vec3 = Vec3 { x: 0.0, y: 1.0, z: 0.0 };
    pub const Z: Vec3 = Vec3 { x: 0.0, y: 0.0, z: 1.0 };

    #[inline]
    pub fn new(x: f32, y: f32, z: f32) -> Vec3 {
        Vec3 { x, y, z }
    }

    #[inline]
    pub fn splat(v: f32) -> Vec3 {
        Vec3::new(v, v, v)
    }

    #[inline]
    pub fn dot(self, o: Vec3) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z
    }

    #[inline]
    pub fn cross(self, o: Vec3) -> Vec3 {
        Vec3::new(
            self.y * o.z - self.z * o.y,
            self.z * o.x - self.x * o.z,
            self.x * o.y - self.y * o.x,
        )
    }

    #[inline]
    pub fn norm(self) -> f32 {
        self.dot(self).sqrt()
    }

    #[inline]
    pub fn norm_sq(self) -> f32 {
        self.dot(self)
    }

    #[inline]
    pub fn normalized(self) -> Vec3 {
        let n = self.norm();
        if n > 0.0 {
            self / n
        } else {
            Vec3::ZERO
        }
    }

    #[inline]
    pub fn min(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.min(o.x), self.y.min(o.y), self.z.min(o.z))
    }

    #[inline]
    pub fn max(self, o: Vec3) -> Vec3 {
        Vec3::new(self.x.max(o.x), self.y.max(o.y), self.z.max(o.z))
    }

    #[inline]
    pub fn lerp(self, o: Vec3, t: f32) -> Vec3 {
        self + (o - self) * t
    }

    #[inline]
    pub fn xy(self) -> Vec2 {
        Vec2::new(self.x, self.y)
    }

    #[inline]
    pub fn extend(self, w: f32) -> Vec4 {
        Vec4 { x: self.x, y: self.y, z: self.z, w }
    }
}

impl Vec4 {
    #[inline]
    pub fn new(x: f32, y: f32, z: f32, w: f32) -> Vec4 {
        Vec4 { x, y, z, w }
    }

    #[inline]
    pub fn xyz(self) -> Vec3 {
        Vec3::new(self.x, self.y, self.z)
    }

    #[inline]
    pub fn dot(self, o: Vec4) -> f32 {
        self.x * o.x + self.y * o.y + self.z * o.z + self.w * o.w
    }
}

macro_rules! impl_ops {
    ($t:ty { $($f:ident),+ }) => {
        impl Add for $t {
            type Output = $t;
            #[inline]
            fn add(self, o: $t) -> $t { Self { $($f: self.$f + o.$f),+ } }
        }
        impl Sub for $t {
            type Output = $t;
            #[inline]
            fn sub(self, o: $t) -> $t { Self { $($f: self.$f - o.$f),+ } }
        }
        impl Mul<f32> for $t {
            type Output = $t;
            #[inline]
            fn mul(self, s: f32) -> $t { Self { $($f: self.$f * s),+ } }
        }
        impl Mul<$t> for $t {
            type Output = $t;
            #[inline]
            fn mul(self, o: $t) -> $t { Self { $($f: self.$f * o.$f),+ } }
        }
        impl Div<f32> for $t {
            type Output = $t;
            #[inline]
            fn div(self, s: f32) -> $t { Self { $($f: self.$f / s),+ } }
        }
        impl Neg for $t {
            type Output = $t;
            #[inline]
            fn neg(self) -> $t { Self { $($f: -self.$f),+ } }
        }
        impl AddAssign for $t {
            #[inline]
            fn add_assign(&mut self, o: $t) { $(self.$f += o.$f;)+ }
        }
    };
}

impl_ops!(Vec2 { x, y });
impl_ops!(Vec3 { x, y, z });
impl_ops!(Vec4 { x, y, z, w });

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f32, b: f32) -> bool {
        (a - b).abs() < 1e-5
    }

    #[test]
    fn dot_and_cross() {
        assert_eq!(Vec3::X.dot(Vec3::Y), 0.0);
        assert_eq!(Vec3::X.cross(Vec3::Y), Vec3::Z);
        assert_eq!(Vec3::Y.cross(Vec3::X), -Vec3::Z);
    }

    #[test]
    fn norm_and_normalize() {
        let v = Vec3::new(3.0, 4.0, 0.0);
        assert!(close(v.norm(), 5.0));
        assert!(close(v.normalized().norm(), 1.0));
        assert_eq!(Vec3::ZERO.normalized(), Vec3::ZERO);
    }

    #[test]
    fn lerp_endpoints() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(-1.0, 0.0, 5.0);
        assert_eq!(a.lerp(b, 0.0), a);
        assert_eq!(a.lerp(b, 1.0), b);
        assert_eq!(a.lerp(b, 0.5), Vec3::new(0.0, 1.0, 4.0));
    }

    #[test]
    fn vec2_perp_orthogonal() {
        let v = Vec2::new(3.0, -2.0);
        assert!(close(v.dot(v.perp()), 0.0));
        assert!(close(v.perp().norm(), v.norm()));
    }

    #[test]
    fn elementwise_ops() {
        let a = Vec3::new(1.0, 2.0, 3.0);
        let b = Vec3::new(4.0, 5.0, 6.0);
        assert_eq!(a + b, Vec3::new(5.0, 7.0, 9.0));
        assert_eq!(b - a, Vec3::new(3.0, 3.0, 3.0));
        assert_eq!(a * 2.0, Vec3::new(2.0, 4.0, 6.0));
        assert_eq!(a * b, Vec3::new(4.0, 10.0, 18.0));
        assert_eq!(b / 2.0, Vec3::new(2.0, 2.5, 3.0));
    }

    #[test]
    fn min_max() {
        let a = Vec3::new(1.0, 5.0, 3.0);
        let b = Vec3::new(2.0, 4.0, 3.0);
        assert_eq!(a.min(b), Vec3::new(1.0, 4.0, 3.0));
        assert_eq!(a.max(b), Vec3::new(2.0, 5.0, 3.0));
    }

    #[test]
    fn extend_xyz_roundtrip() {
        let v = Vec3::new(1.0, 2.0, 3.0);
        assert_eq!(v.extend(4.0).xyz(), v);
    }
}
