//! Geometry and numeric substrates for the 3DGS pipeline: small fixed-size
//! linear algebra, quaternions, spherical harmonics, 2×2 symmetric
//! eigendecomposition and Morton (Z-order) codes.

pub mod eigen;
pub mod fexp;
pub mod mat;
pub mod morton;
pub mod quat;
pub mod sh;
pub mod simd;
pub mod vec;

pub use eigen::{eigvals2x2, Eigen2};
pub use mat::{Mat3, Mat4};
pub use morton::{morton_decode2, morton_decode3, morton_encode2, morton_encode3};
pub use quat::Quat;
pub use simd::{F32x8, Mask8};
pub use vec::{Vec2, Vec3, Vec4};
