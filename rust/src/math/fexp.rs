//! Fast `exp(-e)` for the rasterization hot loop.
//!
//! Density evaluation (Eq. 1) calls exp once per (pixel, Gaussian) pair;
//! after support culling it is the single largest cost in the native
//! rasterizer (EXPERIMENTS.md §Perf). This range-reduced polynomial
//! (2⁻ⁿ·P(r), |r| ≤ ln2/2, 5th-order) has ≤ 3e-6 relative error over the
//! domain the rasterizer uses (e ∈ [0, 4.5]) — far below the 1/255 alpha
//! quantum — at roughly a third of `expf`'s latency.

/// exp(-e) for e ∈ [0, ~87]. Max relative error ≈ 3e-6.
#[inline(always)]
pub fn fast_exp_neg(e: f32) -> f32 {
    debug_assert!(e >= 0.0);
    const LOG2E: f32 = std::f32::consts::LOG2_E;
    const LN2: f32 = std::f32::consts::LN_2;
    let x = -e;
    // Round-to-nearest via the 1.5·2²³ magic constant (baseline x86-64 has
    // no roundss; `f32::round` would be a libm call in this loop).
    const MAGIC: f32 = 12_582_912.0; // 1.5 * 2^23
    let n = (x * LOG2E + MAGIC) - MAGIC;
    let r = x - n * LN2; // |r| <= ln2/2
    // exp(r) ≈ 5th-order Taylor (remainder r⁶/720 ≤ 2.4e-6 relative).
    let p = 1.0
        + r * (1.0
            + r * (0.5 + r * (1.0 / 6.0 + r * (1.0 / 24.0 + r * (1.0 / 120.0)))));
    // Scale by 2^n through the exponent bits (n ≥ -126 here).
    let bits = (((n as i32) + 127) << 23) as u32;
    p * f32::from_bits(bits)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn matches_libm_on_raster_domain() {
        check("fast_exp_neg accuracy", 2048, |rng| {
            let e = rng.range(0.0, 4.5);
            let want = (-e).exp();
            let got = fast_exp_neg(e);
            let rel = ((got - want) / want).abs();
            assert!(rel < 5e-6, "e={e}: {got} vs {want} (rel {rel})");
        });
    }

    #[test]
    fn endpoints() {
        assert!((fast_exp_neg(0.0) - 1.0).abs() < 1e-6);
        let want = (-4.5f32).exp();
        assert!((fast_exp_neg(4.5) - want).abs() / want < 5e-6);
    }

    #[test]
    fn monotone_decreasing() {
        let mut last = f32::INFINITY;
        for i in 0..450 {
            let v = fast_exp_neg(i as f32 * 0.01);
            assert!(v <= last + 1e-7, "not monotone at {i}");
            last = v;
        }
    }

    #[test]
    fn larger_arguments_do_not_blow_up() {
        // Outside the raster domain but reachable via odd conics: stays
        // finite and tiny.
        for e in [10.0f32, 40.0, 80.0] {
            let v = fast_exp_neg(e);
            assert!(v.is_finite() && v >= 0.0 && v < 1e-4);
        }
    }
}
