//! Portable 8-wide f32 SIMD lanes for the per-pair hot loops (ISSUE 6).
//!
//! `F32x8`/`Mask8` expose *only* lane-wise operations — add/sub/mul/div/
//! sqrt/abs/neg, min/max, ordered compares and mask select — so every
//! lane executes exactly the scalar op sequence. There is deliberately
//! no horizontal reduction and no FMA: IEEE 754 `+ − × ÷ √` are
//! correctly rounded, so a lane-wise kernel that keeps the scalar
//! operation order is **bit-identical** to the scalar kernel (enforced
//! by `tests/kernel_parity.rs`). Transcendentals (`exp`) have no such
//! guarantee and stay scalar per lane in the callers.
//!
//! Bit-parity contract for `min`/`max`: the second operand must be a
//! non-NaN value at every call site. Under that contract x86 `minps`
//! ("return second operand on NaN"), AArch64 `FMINNM` and Rust's scalar
//! `f32::min` (minNum) all agree bit-for-bit; with a NaN *second*
//! operand they would not.
//!
//! Backends (selected at compile time, no runtime dispatch):
//!   - x86_64 + AVX2: one `__m256`
//!   - x86_64 baseline: two SSE2 `__m128`
//!   - aarch64: two NEON `float32x4_t` (`vminnmq`/`vmaxnmq`, matching
//!     the scalar FMINNM/FMAXNM that `f32::min`/`max` compile to there)
//!   - anything else: plain `[f32; 8]` scalar fallback

use std::fmt;
use std::ops::{Add, BitAnd, BitOr, Div, Mul, Neg, Not, Sub};

/// Eight f32 lanes.
#[derive(Clone, Copy)]
pub struct F32x8(imp::V);

/// Per-lane boolean mask produced by the compare operations.
#[derive(Clone, Copy)]
pub struct Mask8(imp::M);

impl F32x8 {
    pub const LANES: usize = 8;

    #[inline(always)]
    pub fn splat(x: f32) -> F32x8 {
        F32x8(imp::splat(x))
    }

    #[inline(always)]
    pub fn from_array(a: [f32; 8]) -> F32x8 {
        F32x8(imp::from_array(a))
    }

    #[inline(always)]
    pub fn to_array(self) -> [f32; 8] {
        imp::to_array(self.0)
    }

    /// Lane-wise IEEE-754 bit pattern of each lane. A pure bitcast —
    /// bit-identical to `f32::to_bits` per lane on every backend — used
    /// by the binning stage to pack depth sort keys.
    #[inline(always)]
    pub fn to_bits(self) -> [u32; 8] {
        imp::to_array(self.0).map(f32::to_bits)
    }

    /// `[0.0, 1.0, …, 7.0]` — exact small integers, so
    /// `splat(base as f32) + iota()` is bitwise `(base + k) as f32` for
    /// any pixel coordinate (all well below 2²⁴).
    #[inline(always)]
    pub fn iota() -> F32x8 {
        F32x8::from_array([0.0, 1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0])
    }

    /// Unaligned load of the first 8 elements of `src`.
    #[inline(always)]
    pub fn load(src: &[f32]) -> F32x8 {
        assert!(src.len() >= 8, "F32x8::load needs 8 elements");
        // SAFETY: length checked above; loads are unaligned.
        F32x8(unsafe { imp::load(src.as_ptr()) })
    }

    /// Unaligned store into the first 8 elements of `dst`.
    #[inline(always)]
    pub fn store(self, dst: &mut [f32]) {
        assert!(dst.len() >= 8, "F32x8::store needs 8 elements");
        // SAFETY: length checked above; stores are unaligned.
        unsafe { imp::store(dst.as_mut_ptr(), self.0) }
    }

    #[inline(always)]
    pub fn sqrt(self) -> F32x8 {
        F32x8(imp::sqrt(self.0))
    }

    #[inline(always)]
    pub fn abs(self) -> F32x8 {
        F32x8(imp::abs(self.0))
    }

    /// Lane-wise minimum. `o` must be non-NaN in every lane (see module
    /// docs) for bit parity with scalar `f32::min`.
    #[inline(always)]
    pub fn min(self, o: F32x8) -> F32x8 {
        F32x8(imp::min(self.0, o.0))
    }

    /// Lane-wise maximum. `o` must be non-NaN in every lane (see module
    /// docs) for bit parity with scalar `f32::max`.
    #[inline(always)]
    pub fn max(self, o: F32x8) -> F32x8 {
        F32x8(imp::max(self.0, o.0))
    }

    /// Ordered `<` (NaN lanes compare false, like scalar `<`).
    #[inline(always)]
    pub fn lt(self, o: F32x8) -> Mask8 {
        Mask8(imp::lt(self.0, o.0))
    }

    /// Ordered `<=`.
    #[inline(always)]
    pub fn le(self, o: F32x8) -> Mask8 {
        Mask8(imp::le(self.0, o.0))
    }

    /// Ordered `>`.
    #[inline(always)]
    pub fn gt(self, o: F32x8) -> Mask8 {
        Mask8(imp::gt(self.0, o.0))
    }

    /// Ordered `>=`.
    #[inline(always)]
    pub fn ge(self, o: F32x8) -> Mask8 {
        Mask8(imp::ge(self.0, o.0))
    }

    /// Per-lane `if m { a } else { b }` (bitwise blend; both sides are
    /// already evaluated, so discarded lanes must be side-effect free).
    #[inline(always)]
    pub fn select(m: Mask8, a: F32x8, b: F32x8) -> F32x8 {
        F32x8(imp::select(m.0, a.0, b.0))
    }
}

impl Add for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn add(self, o: F32x8) -> F32x8 {
        F32x8(imp::add(self.0, o.0))
    }
}

impl Sub for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn sub(self, o: F32x8) -> F32x8 {
        F32x8(imp::sub(self.0, o.0))
    }
}

impl Mul for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn mul(self, o: F32x8) -> F32x8 {
        F32x8(imp::mul(self.0, o.0))
    }
}

impl Div for F32x8 {
    type Output = F32x8;
    #[inline(always)]
    fn div(self, o: F32x8) -> F32x8 {
        F32x8(imp::div(self.0, o.0))
    }
}

impl Neg for F32x8 {
    type Output = F32x8;
    /// Sign-bit flip, bitwise identical to scalar `-x` (NaNs included).
    #[inline(always)]
    fn neg(self) -> F32x8 {
        F32x8(imp::neg(self.0))
    }
}

impl fmt::Debug for F32x8 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "F32x8({:?})", self.to_array())
    }
}

impl Mask8 {
    /// Lane k → bit k.
    #[inline(always)]
    pub fn bitmask(self) -> u32 {
        imp::bitmask(self.0)
    }

    #[inline(always)]
    pub fn any(self) -> bool {
        self.bitmask() != 0
    }

    #[inline(always)]
    pub fn all(self) -> bool {
        self.bitmask() == 0xff
    }

    /// Number of set lanes.
    #[inline(always)]
    pub fn count(self) -> u32 {
        self.bitmask().count_ones()
    }

    #[inline(always)]
    pub fn test(self, lane: usize) -> bool {
        debug_assert!(lane < 8);
        (self.bitmask() >> lane) & 1 == 1
    }

    /// Mask with the first `n` lanes set (`n` is clamped to 8) — the
    /// tail mask for partial 8-wide chunks.
    #[inline(always)]
    pub fn first_n(n: usize) -> Mask8 {
        // n and the iota lanes are exact small integers in f32.
        F32x8::iota().lt(F32x8::splat(n.min(8) as f32))
    }
}

impl BitAnd for Mask8 {
    type Output = Mask8;
    #[inline(always)]
    fn bitand(self, o: Mask8) -> Mask8 {
        Mask8(imp::m_and(self.0, o.0))
    }
}

impl BitOr for Mask8 {
    type Output = Mask8;
    #[inline(always)]
    fn bitor(self, o: Mask8) -> Mask8 {
        Mask8(imp::m_or(self.0, o.0))
    }
}

impl Not for Mask8 {
    type Output = Mask8;
    #[inline(always)]
    fn not(self) -> Mask8 {
        Mask8(imp::m_not(self.0))
    }
}

impl fmt::Debug for Mask8 {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Mask8({:#010b})", self.bitmask())
    }
}

// ---------------------------------------------------------------------------
// x86_64 with AVX2 compiled in: one 256-bit register.
// ---------------------------------------------------------------------------
#[cfg(all(target_arch = "x86_64", target_feature = "avx2"))]
#[allow(unused_unsafe)]
mod imp {
    use core::arch::x86_64::*;

    pub type V = __m256;
    pub type M = __m256;

    #[inline(always)]
    pub fn splat(x: f32) -> V {
        unsafe { _mm256_set1_ps(x) }
    }

    #[inline(always)]
    pub fn from_array(a: [f32; 8]) -> V {
        unsafe { _mm256_loadu_ps(a.as_ptr()) }
    }

    #[inline(always)]
    pub fn to_array(v: V) -> [f32; 8] {
        let mut out = [0.0f32; 8];
        unsafe { _mm256_storeu_ps(out.as_mut_ptr(), v) };
        out
    }

    /// SAFETY: caller guarantees 8 readable f32 at `p`.
    #[inline(always)]
    pub unsafe fn load(p: *const f32) -> V {
        _mm256_loadu_ps(p)
    }

    /// SAFETY: caller guarantees 8 writable f32 at `p`.
    #[inline(always)]
    pub unsafe fn store(p: *mut f32, v: V) {
        _mm256_storeu_ps(p, v)
    }

    #[inline(always)]
    pub fn add(a: V, b: V) -> V {
        unsafe { _mm256_add_ps(a, b) }
    }

    #[inline(always)]
    pub fn sub(a: V, b: V) -> V {
        unsafe { _mm256_sub_ps(a, b) }
    }

    #[inline(always)]
    pub fn mul(a: V, b: V) -> V {
        unsafe { _mm256_mul_ps(a, b) }
    }

    #[inline(always)]
    pub fn div(a: V, b: V) -> V {
        unsafe { _mm256_div_ps(a, b) }
    }

    #[inline(always)]
    pub fn sqrt(a: V) -> V {
        unsafe { _mm256_sqrt_ps(a) }
    }

    #[inline(always)]
    pub fn neg(a: V) -> V {
        unsafe { _mm256_xor_ps(a, _mm256_set1_ps(-0.0)) }
    }

    #[inline(always)]
    pub fn abs(a: V) -> V {
        unsafe { _mm256_andnot_ps(_mm256_set1_ps(-0.0), a) }
    }

    #[inline(always)]
    pub fn min(a: V, b: V) -> V {
        unsafe { _mm256_min_ps(a, b) }
    }

    #[inline(always)]
    pub fn max(a: V, b: V) -> V {
        unsafe { _mm256_max_ps(a, b) }
    }

    #[inline(always)]
    pub fn lt(a: V, b: V) -> M {
        unsafe { _mm256_cmp_ps::<_CMP_LT_OQ>(a, b) }
    }

    #[inline(always)]
    pub fn le(a: V, b: V) -> M {
        unsafe { _mm256_cmp_ps::<_CMP_LE_OQ>(a, b) }
    }

    #[inline(always)]
    pub fn gt(a: V, b: V) -> M {
        unsafe { _mm256_cmp_ps::<_CMP_GT_OQ>(a, b) }
    }

    #[inline(always)]
    pub fn ge(a: V, b: V) -> M {
        unsafe { _mm256_cmp_ps::<_CMP_GE_OQ>(a, b) }
    }

    #[inline(always)]
    pub fn select(m: M, a: V, b: V) -> V {
        // blendv picks its SECOND value where the mask bit is set.
        unsafe { _mm256_blendv_ps(b, a, m) }
    }

    #[inline(always)]
    pub fn m_and(a: M, b: M) -> M {
        unsafe { _mm256_and_ps(a, b) }
    }

    #[inline(always)]
    pub fn m_or(a: M, b: M) -> M {
        unsafe { _mm256_or_ps(a, b) }
    }

    #[inline(always)]
    pub fn m_not(a: M) -> M {
        unsafe { _mm256_xor_ps(a, _mm256_castsi256_ps(_mm256_set1_epi32(-1))) }
    }

    #[inline(always)]
    pub fn bitmask(m: M) -> u32 {
        (unsafe { _mm256_movemask_ps(m) } as u32) & 0xff
    }
}

// ---------------------------------------------------------------------------
// x86_64 baseline: two SSE2 128-bit halves (SSE2 is part of the x86_64
// ABI, so no runtime detection is needed).
// ---------------------------------------------------------------------------
#[cfg(all(target_arch = "x86_64", not(target_feature = "avx2")))]
#[allow(unused_unsafe)]
mod imp {
    use core::arch::x86_64::*;

    pub type V = (__m128, __m128);
    pub type M = (__m128, __m128);

    #[inline(always)]
    pub fn splat(x: f32) -> V {
        unsafe { (_mm_set1_ps(x), _mm_set1_ps(x)) }
    }

    #[inline(always)]
    pub fn from_array(a: [f32; 8]) -> V {
        unsafe { (_mm_loadu_ps(a.as_ptr()), _mm_loadu_ps(a.as_ptr().add(4))) }
    }

    #[inline(always)]
    pub fn to_array(v: V) -> [f32; 8] {
        let mut out = [0.0f32; 8];
        unsafe {
            _mm_storeu_ps(out.as_mut_ptr(), v.0);
            _mm_storeu_ps(out.as_mut_ptr().add(4), v.1);
        }
        out
    }

    /// SAFETY: caller guarantees 8 readable f32 at `p`.
    #[inline(always)]
    pub unsafe fn load(p: *const f32) -> V {
        (_mm_loadu_ps(p), _mm_loadu_ps(p.add(4)))
    }

    /// SAFETY: caller guarantees 8 writable f32 at `p`.
    #[inline(always)]
    pub unsafe fn store(p: *mut f32, v: V) {
        _mm_storeu_ps(p, v.0);
        _mm_storeu_ps(p.add(4), v.1);
    }

    #[inline(always)]
    pub fn add(a: V, b: V) -> V {
        unsafe { (_mm_add_ps(a.0, b.0), _mm_add_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn sub(a: V, b: V) -> V {
        unsafe { (_mm_sub_ps(a.0, b.0), _mm_sub_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn mul(a: V, b: V) -> V {
        unsafe { (_mm_mul_ps(a.0, b.0), _mm_mul_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn div(a: V, b: V) -> V {
        unsafe { (_mm_div_ps(a.0, b.0), _mm_div_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn sqrt(a: V) -> V {
        unsafe { (_mm_sqrt_ps(a.0), _mm_sqrt_ps(a.1)) }
    }

    #[inline(always)]
    pub fn neg(a: V) -> V {
        unsafe {
            let s = _mm_set1_ps(-0.0);
            (_mm_xor_ps(a.0, s), _mm_xor_ps(a.1, s))
        }
    }

    #[inline(always)]
    pub fn abs(a: V) -> V {
        unsafe {
            let s = _mm_set1_ps(-0.0);
            (_mm_andnot_ps(s, a.0), _mm_andnot_ps(s, a.1))
        }
    }

    #[inline(always)]
    pub fn min(a: V, b: V) -> V {
        unsafe { (_mm_min_ps(a.0, b.0), _mm_min_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn max(a: V, b: V) -> V {
        unsafe { (_mm_max_ps(a.0, b.0), _mm_max_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn lt(a: V, b: V) -> M {
        unsafe { (_mm_cmplt_ps(a.0, b.0), _mm_cmplt_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn le(a: V, b: V) -> M {
        unsafe { (_mm_cmple_ps(a.0, b.0), _mm_cmple_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn gt(a: V, b: V) -> M {
        unsafe { (_mm_cmpgt_ps(a.0, b.0), _mm_cmpgt_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn ge(a: V, b: V) -> M {
        unsafe { (_mm_cmpge_ps(a.0, b.0), _mm_cmpge_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn select(m: M, a: V, b: V) -> V {
        // SSE2 has no blendv: (m & a) | (!m & b).
        unsafe {
            (
                _mm_or_ps(_mm_and_ps(m.0, a.0), _mm_andnot_ps(m.0, b.0)),
                _mm_or_ps(_mm_and_ps(m.1, a.1), _mm_andnot_ps(m.1, b.1)),
            )
        }
    }

    #[inline(always)]
    pub fn m_and(a: M, b: M) -> M {
        unsafe { (_mm_and_ps(a.0, b.0), _mm_and_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn m_or(a: M, b: M) -> M {
        unsafe { (_mm_or_ps(a.0, b.0), _mm_or_ps(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn m_not(a: M) -> M {
        unsafe {
            let ones = _mm_castsi128_ps(_mm_set1_epi32(-1));
            (_mm_xor_ps(a.0, ones), _mm_xor_ps(a.1, ones))
        }
    }

    #[inline(always)]
    pub fn bitmask(m: M) -> u32 {
        unsafe { (_mm_movemask_ps(m.0) as u32 & 0xf) | ((_mm_movemask_ps(m.1) as u32 & 0xf) << 4) }
    }
}

// ---------------------------------------------------------------------------
// aarch64: two NEON 128-bit halves. min/max use FMINNM/FMAXNM so lanes
// match the scalar f32::min/max codegen on this architecture.
// ---------------------------------------------------------------------------
#[cfg(target_arch = "aarch64")]
#[allow(unused_unsafe)]
mod imp {
    use core::arch::aarch64::*;

    pub type V = (float32x4_t, float32x4_t);
    pub type M = (uint32x4_t, uint32x4_t);

    #[inline(always)]
    pub fn splat(x: f32) -> V {
        unsafe { (vdupq_n_f32(x), vdupq_n_f32(x)) }
    }

    #[inline(always)]
    pub fn from_array(a: [f32; 8]) -> V {
        unsafe { (vld1q_f32(a.as_ptr()), vld1q_f32(a.as_ptr().add(4))) }
    }

    #[inline(always)]
    pub fn to_array(v: V) -> [f32; 8] {
        let mut out = [0.0f32; 8];
        unsafe {
            vst1q_f32(out.as_mut_ptr(), v.0);
            vst1q_f32(out.as_mut_ptr().add(4), v.1);
        }
        out
    }

    /// SAFETY: caller guarantees 8 readable f32 at `p`.
    #[inline(always)]
    pub unsafe fn load(p: *const f32) -> V {
        (vld1q_f32(p), vld1q_f32(p.add(4)))
    }

    /// SAFETY: caller guarantees 8 writable f32 at `p`.
    #[inline(always)]
    pub unsafe fn store(p: *mut f32, v: V) {
        vst1q_f32(p, v.0);
        vst1q_f32(p.add(4), v.1);
    }

    #[inline(always)]
    pub fn add(a: V, b: V) -> V {
        unsafe { (vaddq_f32(a.0, b.0), vaddq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn sub(a: V, b: V) -> V {
        unsafe { (vsubq_f32(a.0, b.0), vsubq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn mul(a: V, b: V) -> V {
        unsafe { (vmulq_f32(a.0, b.0), vmulq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn div(a: V, b: V) -> V {
        unsafe { (vdivq_f32(a.0, b.0), vdivq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn sqrt(a: V) -> V {
        unsafe { (vsqrtq_f32(a.0), vsqrtq_f32(a.1)) }
    }

    #[inline(always)]
    pub fn neg(a: V) -> V {
        unsafe { (vnegq_f32(a.0), vnegq_f32(a.1)) }
    }

    #[inline(always)]
    pub fn abs(a: V) -> V {
        unsafe { (vabsq_f32(a.0), vabsq_f32(a.1)) }
    }

    #[inline(always)]
    pub fn min(a: V, b: V) -> V {
        unsafe { (vminnmq_f32(a.0, b.0), vminnmq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn max(a: V, b: V) -> V {
        unsafe { (vmaxnmq_f32(a.0, b.0), vmaxnmq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn lt(a: V, b: V) -> M {
        unsafe { (vcltq_f32(a.0, b.0), vcltq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn le(a: V, b: V) -> M {
        unsafe { (vcleq_f32(a.0, b.0), vcleq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn gt(a: V, b: V) -> M {
        unsafe { (vcgtq_f32(a.0, b.0), vcgtq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn ge(a: V, b: V) -> M {
        unsafe { (vcgeq_f32(a.0, b.0), vcgeq_f32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn select(m: M, a: V, b: V) -> V {
        unsafe { (vbslq_f32(m.0, a.0, b.0), vbslq_f32(m.1, a.1, b.1)) }
    }

    #[inline(always)]
    pub fn m_and(a: M, b: M) -> M {
        unsafe { (vandq_u32(a.0, b.0), vandq_u32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn m_or(a: M, b: M) -> M {
        unsafe { (vorrq_u32(a.0, b.0), vorrq_u32(a.1, b.1)) }
    }

    #[inline(always)]
    pub fn m_not(a: M) -> M {
        unsafe { (vmvnq_u32(a.0), vmvnq_u32(a.1)) }
    }

    #[inline(always)]
    pub fn bitmask(m: M) -> u32 {
        unsafe {
            let lo = [1u32, 2, 4, 8];
            let hi = [16u32, 32, 64, 128];
            let bits_lo = vld1q_u32(lo.as_ptr());
            let bits_hi = vld1q_u32(hi.as_ptr());
            vaddvq_u32(vandq_u32(m.0, bits_lo)) | vaddvq_u32(vandq_u32(m.1, bits_hi))
        }
    }
}

// ---------------------------------------------------------------------------
// Portable scalar fallback: one scalar op per lane, which is the parity
// reference by construction.
// ---------------------------------------------------------------------------
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
mod imp {
    pub type V = [f32; 8];
    pub type M = u8;

    #[inline(always)]
    fn map2(a: V, b: V, f: impl Fn(f32, f32) -> f32) -> V {
        let mut out = [0.0f32; 8];
        for k in 0..8 {
            out[k] = f(a[k], b[k]);
        }
        out
    }

    #[inline(always)]
    fn cmp2(a: V, b: V, f: impl Fn(f32, f32) -> bool) -> M {
        let mut m = 0u8;
        for k in 0..8 {
            if f(a[k], b[k]) {
                m |= 1 << k;
            }
        }
        m
    }

    #[inline(always)]
    pub fn splat(x: f32) -> V {
        [x; 8]
    }

    #[inline(always)]
    pub fn from_array(a: [f32; 8]) -> V {
        a
    }

    #[inline(always)]
    pub fn to_array(v: V) -> [f32; 8] {
        v
    }

    /// SAFETY: caller guarantees 8 readable f32 at `p`.
    #[inline(always)]
    pub unsafe fn load(p: *const f32) -> V {
        let mut out = [0.0f32; 8];
        for (k, o) in out.iter_mut().enumerate() {
            *o = *p.add(k);
        }
        out
    }

    /// SAFETY: caller guarantees 8 writable f32 at `p`.
    #[inline(always)]
    pub unsafe fn store(p: *mut f32, v: V) {
        for (k, x) in v.iter().enumerate() {
            *p.add(k) = *x;
        }
    }

    #[inline(always)]
    pub fn add(a: V, b: V) -> V {
        map2(a, b, |x, y| x + y)
    }

    #[inline(always)]
    pub fn sub(a: V, b: V) -> V {
        map2(a, b, |x, y| x - y)
    }

    #[inline(always)]
    pub fn mul(a: V, b: V) -> V {
        map2(a, b, |x, y| x * y)
    }

    #[inline(always)]
    pub fn div(a: V, b: V) -> V {
        map2(a, b, |x, y| x / y)
    }

    #[inline(always)]
    pub fn sqrt(a: V) -> V {
        a.map(|x| x.sqrt())
    }

    #[inline(always)]
    pub fn neg(a: V) -> V {
        a.map(|x| -x)
    }

    #[inline(always)]
    pub fn abs(a: V) -> V {
        a.map(|x| x.abs())
    }

    #[inline(always)]
    pub fn min(a: V, b: V) -> V {
        map2(a, b, |x, y| x.min(y))
    }

    #[inline(always)]
    pub fn max(a: V, b: V) -> V {
        map2(a, b, |x, y| x.max(y))
    }

    #[inline(always)]
    pub fn lt(a: V, b: V) -> M {
        cmp2(a, b, |x, y| x < y)
    }

    #[inline(always)]
    pub fn le(a: V, b: V) -> M {
        cmp2(a, b, |x, y| x <= y)
    }

    #[inline(always)]
    pub fn gt(a: V, b: V) -> M {
        cmp2(a, b, |x, y| x > y)
    }

    #[inline(always)]
    pub fn ge(a: V, b: V) -> M {
        cmp2(a, b, |x, y| x >= y)
    }

    #[inline(always)]
    pub fn select(m: M, a: V, b: V) -> V {
        let mut out = [0.0f32; 8];
        for k in 0..8 {
            out[k] = if (m >> k) & 1 == 1 { a[k] } else { b[k] };
        }
        out
    }

    #[inline(always)]
    pub fn m_and(a: M, b: M) -> M {
        a & b
    }

    #[inline(always)]
    pub fn m_or(a: M, b: M) -> M {
        a | b
    }

    #[inline(always)]
    pub fn m_not(a: M) -> M {
        !a
    }

    #[inline(always)]
    pub fn bitmask(m: M) -> u32 {
        m as u32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::hint::black_box;

    /// Edge values for lane-wise parity checks. Subnormals, infinities
    /// and NaN are included; the scalar reference runs on the exact same
    /// hardware ops, so results must agree to the bit.
    const SPECIALS: [f32; 12] = [
        0.0,
        -0.0,
        1.0,
        -1.0,
        0.5,
        -255.25,
        1.0e-40, // subnormal
        f32::MIN_POSITIVE,
        3.0e38,
        f32::INFINITY,
        f32::NEG_INFINITY,
        f32::NAN,
    ];

    fn lanes_of(i: usize) -> [f32; 8] {
        let mut a = [0.0f32; 8];
        for (k, v) in a.iter_mut().enumerate() {
            *v = SPECIALS[(i + k) % SPECIALS.len()];
        }
        a
    }

    fn assert_bits(got: [f32; 8], want: [f32; 8], what: &str) {
        for k in 0..8 {
            assert_eq!(
                got[k].to_bits(),
                want[k].to_bits(),
                "{what} lane {k}: {} vs {}",
                got[k],
                want[k]
            );
        }
    }

    #[test]
    fn roundtrip_and_load_store() {
        let a = lanes_of(3);
        assert_bits(F32x8::from_array(a).to_array(), a, "roundtrip");
        let buf: Vec<f32> = (0..11).map(|i| i as f32 * 1.5).collect();
        let v = F32x8::load(&buf[2..]);
        let mut out = vec![0.0f32; 9];
        v.store(&mut out[1..]);
        assert_eq!(&out[1..9], &buf[2..10]);
    }

    #[test]
    fn arithmetic_matches_scalar_bits() {
        for i in 0..SPECIALS.len() {
            for &s in &SPECIALS {
                let a = lanes_of(i);
                let (va, vb) = (F32x8::from_array(a), F32x8::splat(s));
                let scalar = |f: fn(f32, f32) -> f32| {
                    let mut w = [0.0f32; 8];
                    for k in 0..8 {
                        w[k] = f(black_box(a[k]), black_box(s));
                    }
                    w
                };
                assert_bits((va + vb).to_array(), scalar(|x, y| x + y), "add");
                assert_bits((va - vb).to_array(), scalar(|x, y| x - y), "sub");
                assert_bits((va * vb).to_array(), scalar(|x, y| x * y), "mul");
                assert_bits((va / vb).to_array(), scalar(|x, y| x / y), "div");
            }
        }
    }

    #[test]
    fn unary_ops_match_scalar_bits() {
        for i in 0..SPECIALS.len() {
            let a = lanes_of(i);
            let va = F32x8::from_array(a);
            let mut sq = [0.0f32; 8];
            let mut ng = [0.0f32; 8];
            let mut ab = [0.0f32; 8];
            for k in 0..8 {
                sq[k] = black_box(a[k]).sqrt();
                ng[k] = -black_box(a[k]);
                ab[k] = black_box(a[k]).abs();
            }
            assert_bits(va.sqrt().to_array(), sq, "sqrt");
            assert_bits((-va).to_array(), ng, "neg");
            assert_bits(va.abs().to_array(), ab, "abs");
        }
    }

    #[test]
    fn min_max_match_scalar_under_contract() {
        // Contract: second operand non-NaN. First operand may be NaN.
        for i in 0..SPECIALS.len() {
            for &s in &SPECIALS {
                if s.is_nan() {
                    continue;
                }
                let a = lanes_of(i);
                let (va, vb) = (F32x8::from_array(a), F32x8::splat(s));
                let mut mn = [0.0f32; 8];
                let mut mx = [0.0f32; 8];
                for k in 0..8 {
                    mn[k] = black_box(a[k]).min(black_box(s));
                    mx[k] = black_box(a[k]).max(black_box(s));
                }
                assert_bits(va.min(vb).to_array(), mn, "min");
                assert_bits(va.max(vb).to_array(), mx, "max");
            }
        }
    }

    #[test]
    fn compares_match_scalar_including_nan() {
        for i in 0..SPECIALS.len() {
            for &s in &SPECIALS {
                let a = lanes_of(i);
                let (va, vb) = (F32x8::from_array(a), F32x8::splat(s));
                let want = |f: fn(f32, f32) -> bool| {
                    let mut m = 0u32;
                    for k in 0..8 {
                        if f(black_box(a[k]), black_box(s)) {
                            m |= 1 << k;
                        }
                    }
                    m
                };
                assert_eq!(va.lt(vb).bitmask(), want(|x, y| x < y), "lt vs {s}");
                assert_eq!(va.le(vb).bitmask(), want(|x, y| x <= y), "le vs {s}");
                assert_eq!(va.gt(vb).bitmask(), want(|x, y| x > y), "gt vs {s}");
                assert_eq!(va.ge(vb).bitmask(), want(|x, y| x >= y), "ge vs {s}");
            }
        }
    }

    #[test]
    fn select_blends_per_lane() {
        let a = F32x8::from_array([1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
        let b = F32x8::splat(-1.0);
        let m = F32x8::iota().lt(F32x8::splat(3.0)); // lanes 0..3
        let got = F32x8::select(m, a, b).to_array();
        assert_eq!(got, [1.0, 2.0, 3.0, -1.0, -1.0, -1.0, -1.0, -1.0]);
        // NaN payloads survive the blend bitwise.
        let nan = F32x8::splat(f32::NAN);
        let picked = F32x8::select(m, nan, a).to_array();
        assert!(picked[0].is_nan() && picked[3] == 4.0);
    }

    #[test]
    fn mask_logic_and_queries() {
        let lo = Mask8::first_n(3);
        assert_eq!(lo.bitmask(), 0b0000_0111);
        assert_eq!(lo.count(), 3);
        assert!(lo.any() && !lo.all());
        assert!(lo.test(2) && !lo.test(3));
        assert_eq!((!lo).bitmask(), 0b1111_1000);
        assert_eq!(Mask8::first_n(0).bitmask(), 0);
        assert_eq!(Mask8::first_n(8).bitmask(), 0xff);
        assert!(Mask8::first_n(8).all());
        assert_eq!(Mask8::first_n(12).bitmask(), 0xff); // clamped
        let hi = !Mask8::first_n(6);
        assert_eq!((lo | hi).bitmask(), 0b1100_0111);
        assert_eq!((lo & hi).bitmask(), 0);
    }

    #[test]
    fn iota_is_exact_integers() {
        let base = 1234usize;
        let v = (F32x8::splat(base as f32) + F32x8::iota()).to_array();
        for (k, x) in v.iter().enumerate() {
            assert_eq!(x.to_bits(), ((base + k) as f32).to_bits());
        }
    }
}
