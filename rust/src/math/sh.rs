//! Real spherical harmonics evaluation (degrees 0–3), matching the
//! reference 3DGS convention: per-Gaussian SH coefficients encode
//! view-dependent color; preprocessing evaluates them along the
//! camera→Gaussian direction.

use super::vec::Vec3;

/// Number of SH coefficients for a maximum degree (per color channel).
pub const fn num_coeffs(degree: usize) -> usize {
    (degree + 1) * (degree + 1)
}

// Real SH constants (as in the 3DGS reference implementation).
// pub(crate): the SIMD preprocess kernel evaluates the same basis
// polynomials lane-wise and must use the identical constants.
pub(crate) const C0: f32 = 0.282_094_79;
pub(crate) const C1: f32 = 0.488_602_51;
pub(crate) const C2: [f32; 5] =
    [1.092_548_4, -1.092_548_4, 0.315_391_57, -1.092_548_4, 0.546_274_2];
pub(crate) const C3: [f32; 7] = [
    -0.590_043_6,
    2.890_611_4,
    -0.457_045_8,
    0.373_176_33,
    -0.457_045_8,
    1.445_305_7,
    -0.590_043_6,
];

/// Evaluate the SH basis functions at unit direction `d` into `out`
/// (length = num_coeffs(degree)).
pub fn eval_basis(degree: usize, d: Vec3, out: &mut [f32]) {
    assert!(degree <= 3, "SH degree {degree} unsupported");
    assert_eq!(out.len(), num_coeffs(degree));
    out[0] = C0;
    if degree == 0 {
        return;
    }
    let (x, y, z) = (d.x, d.y, d.z);
    out[1] = -C1 * y;
    out[2] = C1 * z;
    out[3] = -C1 * x;
    if degree == 1 {
        return;
    }
    let (xx, yy, zz) = (x * x, y * y, z * z);
    let (xy, yz, xz) = (x * y, y * z, x * z);
    out[4] = C2[0] * xy;
    out[5] = C2[1] * yz;
    out[6] = C2[2] * (2.0 * zz - xx - yy);
    out[7] = C2[3] * xz;
    out[8] = C2[4] * (xx - yy);
    if degree == 2 {
        return;
    }
    out[9] = C3[0] * y * (3.0 * xx - yy);
    out[10] = C3[1] * xy * z;
    out[11] = C3[2] * y * (4.0 * zz - xx - yy);
    out[12] = C3[3] * z * (2.0 * zz - 3.0 * xx - 3.0 * yy);
    out[13] = C3[4] * x * (4.0 * zz - xx - yy);
    out[14] = C3[5] * z * (xx - yy);
    out[15] = C3[6] * x * (xx - 3.0 * yy);
}

/// Evaluate an RGB color from interleaved coefficients
/// (`coeffs[c * 3 + channel]`) at direction `d`, with the 3DGS +0.5 offset
/// and clamp-to-positive.
pub fn eval_color(degree: usize, coeffs: &[f32], d: Vec3) -> Vec3 {
    let n = num_coeffs(degree);
    debug_assert_eq!(coeffs.len(), n * 3);
    let mut basis = [0.0f32; 16];
    eval_basis(degree, d, &mut basis[..n]);
    let mut rgb = Vec3::ZERO;
    for (i, &b) in basis[..n].iter().enumerate() {
        rgb += Vec3::new(coeffs[i * 3], coeffs[i * 3 + 1], coeffs[i * 3 + 2]) * b;
    }
    rgb += Vec3::splat(0.5); // 3DGS convention
    rgb.max(Vec3::ZERO)
}

/// Degree-0 inverse: the coefficient that yields `color` from any direction.
pub fn dc_from_color(color: Vec3) -> Vec3 {
    (color - Vec3::splat(0.5)) / C0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn rand_dir(rng: &mut Rng) -> Vec3 {
        loop {
            let v = Vec3::new(rng.normal(), rng.normal(), rng.normal());
            if v.norm() > 1e-3 {
                return v.normalized();
            }
        }
    }

    #[test]
    fn coeff_counts() {
        assert_eq!(num_coeffs(0), 1);
        assert_eq!(num_coeffs(1), 4);
        assert_eq!(num_coeffs(2), 9);
        assert_eq!(num_coeffs(3), 16);
    }

    #[test]
    fn degree0_is_isotropic() {
        let dc = dc_from_color(Vec3::new(0.8, 0.3, 0.1));
        let coeffs = [dc.x, dc.y, dc.z];
        let c1 = eval_color(0, &coeffs, Vec3::X);
        let c2 = eval_color(0, &coeffs, Vec3::new(-0.3, 0.5, 0.8).normalized());
        assert!((c1 - c2).norm() < 1e-6);
        assert!((c1 - Vec3::new(0.8, 0.3, 0.1)).norm() < 1e-5);
    }

    #[test]
    fn basis_orthonormality_monte_carlo() {
        // ∫ Y_i Y_j dΩ = δ_ij; with uniform sphere samples the estimator is
        // 4π E[Y_i Y_j]. Loose tolerance — MC with 60k samples.
        let mut rng = Rng::new(123);
        let n = num_coeffs(2);
        let samples = 60_000;
        let mut acc = vec![0.0f64; n * n];
        let mut basis = vec![0.0f32; n];
        for _ in 0..samples {
            let d = rand_dir(&mut rng);
            eval_basis(2, d, &mut basis);
            for i in 0..n {
                for j in 0..n {
                    acc[i * n + j] += (basis[i] * basis[j]) as f64;
                }
            }
        }
        let norm = 4.0 * std::f64::consts::PI / samples as f64;
        for i in 0..n {
            for j in 0..n {
                let v = acc[i * n + j] * norm;
                let want = if i == j { 1.0 } else { 0.0 };
                assert!(
                    (v - want).abs() < 0.05,
                    "gram[{i}][{j}] = {v} (want {want})"
                );
            }
        }
    }

    #[test]
    fn eval_color_never_negative() {
        check("SH color clamped at zero", 256, |rng| {
            let n = num_coeffs(3);
            let coeffs: Vec<f32> = (0..n * 3).map(|_| rng.range(-2.0, 2.0)).collect();
            let d = {
                let v = Vec3::new(rng.normal(), rng.normal(), rng.normal());
                if v.norm() > 1e-3 { v.normalized() } else { Vec3::Z }
            };
            let c = eval_color(3, &coeffs, d);
            assert!(c.x >= 0.0 && c.y >= 0.0 && c.z >= 0.0);
        });
    }

    #[test]
    fn degree3_smooth_in_direction() {
        // Small direction change ⇒ small color change (continuity).
        let mut rng = Rng::new(9);
        let n = num_coeffs(3);
        let coeffs: Vec<f32> = (0..n * 3).map(|_| rng.range(-0.5, 0.5)).collect();
        let d0 = Vec3::new(0.6, 0.5, 0.62).normalized();
        let d1 = (d0 + Vec3::new(1e-4, -1e-4, 1e-4)).normalized();
        let c0 = eval_color(3, &coeffs, d0);
        let c1 = eval_color(3, &coeffs, d1);
        assert!((c0 - c1).norm() < 1e-2);
    }
}
