//! Unit quaternions for Gaussian orientations and camera poses, including
//! slerp for trajectory interpolation (the paper interpolates sparse
//! real-world camera paths into continuous 90 FPS sequences, Sec. VI-A).

use super::mat::Mat3;
use super::vec::Vec3;

/// Quaternion w + xi + yj + zk.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Quat {
    pub w: f32,
    pub x: f32,
    pub y: f32,
    pub z: f32,
}

impl Quat {
    pub const IDENTITY: Quat = Quat { w: 1.0, x: 0.0, y: 0.0, z: 0.0 };

    pub fn new(w: f32, x: f32, y: f32, z: f32) -> Quat {
        Quat { w, x, y, z }
    }

    pub fn from_axis_angle(axis: Vec3, angle: f32) -> Quat {
        let a = axis.normalized();
        let (s, c) = (angle * 0.5).sin_cos();
        Quat { w: c, x: a.x * s, y: a.y * s, z: a.z * s }
    }

    pub fn dot(self, o: Quat) -> f32 {
        self.w * o.w + self.x * o.x + self.y * o.y + self.z * o.z
    }

    pub fn norm(self) -> f32 {
        self.dot(self).sqrt()
    }

    pub fn normalized(self) -> Quat {
        let n = self.norm();
        if n > 1e-12 {
            Quat { w: self.w / n, x: self.x / n, y: self.y / n, z: self.z / n }
        } else {
            Quat::IDENTITY
        }
    }

    pub fn conj(self) -> Quat {
        Quat { w: self.w, x: -self.x, y: -self.y, z: -self.z }
    }

    /// Hamilton product.
    pub fn mul(self, o: Quat) -> Quat {
        Quat {
            w: self.w * o.w - self.x * o.x - self.y * o.y - self.z * o.z,
            x: self.w * o.x + self.x * o.w + self.y * o.z - self.z * o.y,
            y: self.w * o.y - self.x * o.z + self.y * o.w + self.z * o.x,
            z: self.w * o.z + self.x * o.y - self.y * o.x + self.z * o.w,
        }
    }

    /// Rotate a vector.
    pub fn rotate(self, v: Vec3) -> Vec3 {
        self.to_mat3() * v
    }

    /// Rotation matrix of the (assumed unit) quaternion.
    pub fn to_mat3(self) -> Mat3 {
        let Quat { w, x, y, z } = self.normalized();
        Mat3 {
            m: [
                [
                    1.0 - 2.0 * (y * y + z * z),
                    2.0 * (x * y - w * z),
                    2.0 * (x * z + w * y),
                ],
                [
                    2.0 * (x * y + w * z),
                    1.0 - 2.0 * (x * x + z * z),
                    2.0 * (y * z - w * x),
                ],
                [
                    2.0 * (x * z - w * y),
                    2.0 * (y * z + w * x),
                    1.0 - 2.0 * (x * x + y * y),
                ],
            ],
        }
    }

    /// Spherical linear interpolation (shortest arc).
    pub fn slerp(self, other: Quat, t: f32) -> Quat {
        let mut b = other;
        let mut cos = self.dot(other);
        if cos < 0.0 {
            // Take the shorter path.
            b = Quat { w: -b.w, x: -b.x, y: -b.y, z: -b.z };
            cos = -cos;
        }
        if cos > 0.9995 {
            // Nearly parallel: nlerp.
            return Quat {
                w: self.w + (b.w - self.w) * t,
                x: self.x + (b.x - self.x) * t,
                y: self.y + (b.y - self.y) * t,
                z: self.z + (b.z - self.z) * t,
            }
            .normalized();
        }
        let theta = cos.clamp(-1.0, 1.0).acos();
        let sin = theta.sin();
        let wa = ((1.0 - t) * theta).sin() / sin;
        let wb = (t * theta).sin() / sin;
        Quat {
            w: self.w * wa + b.w * wb,
            x: self.x * wa + b.x * wb,
            y: self.y * wa + b.y * wb,
            z: self.z * wa + b.z * wb,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_rotates_nothing() {
        let v = Vec3::new(1.0, 2.0, 3.0);
        assert!((Quat::IDENTITY.rotate(v) - v).norm() < 1e-6);
    }

    #[test]
    fn axis_angle_quarter_turn() {
        let q = Quat::from_axis_angle(Vec3::Z, std::f32::consts::FRAC_PI_2);
        let v = q.rotate(Vec3::X);
        assert!((v - Vec3::Y).norm() < 1e-5, "{v:?}");
    }

    #[test]
    fn rotation_matrix_orthonormal() {
        let q = Quat::from_axis_angle(Vec3::new(1.0, 1.0, 0.3), 1.1);
        let m = q.to_mat3();
        let should_be_i = m * m.transpose();
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((should_be_i.m[i][j] - want).abs() < 1e-5);
            }
        }
        assert!((m.det() - 1.0).abs() < 1e-4);
    }

    #[test]
    fn mul_composes_rotations() {
        let a = Quat::from_axis_angle(Vec3::Z, 0.4);
        let b = Quat::from_axis_angle(Vec3::X, 0.9);
        let v = Vec3::new(0.2, -1.0, 0.7);
        let seq = a.rotate(b.rotate(v));
        let composed = a.mul(b).rotate(v);
        assert!((seq - composed).norm() < 1e-5);
    }

    #[test]
    fn slerp_endpoints_and_midpoint() {
        let a = Quat::from_axis_angle(Vec3::Z, 0.0);
        let b = Quat::from_axis_angle(Vec3::Z, 1.0);
        assert!((a.slerp(b, 0.0).dot(a).abs() - 1.0).abs() < 1e-5);
        assert!((a.slerp(b, 1.0).dot(b).abs() - 1.0).abs() < 1e-5);
        let mid = a.slerp(b, 0.5);
        let expect = Quat::from_axis_angle(Vec3::Z, 0.5);
        assert!((mid.dot(expect).abs() - 1.0).abs() < 1e-5);
    }

    #[test]
    fn slerp_takes_short_path() {
        let a = Quat::from_axis_angle(Vec3::Z, 0.1);
        let b_long = Quat::from_axis_angle(Vec3::Z, 0.3);
        let b_neg = Quat { w: -b_long.w, x: -b_long.x, y: -b_long.y, z: -b_long.z };
        // Interpolating toward the negated quaternion must give the same rotation.
        let m1 = a.slerp(b_long, 0.5).to_mat3();
        let m2 = a.slerp(b_neg, 0.5).to_mat3();
        for i in 0..3 {
            for j in 0..3 {
                assert!((m1.m[i][j] - m2.m[i][j]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn conj_inverts_unit_rotation() {
        let q = Quat::from_axis_angle(Vec3::new(0.3, 0.8, -0.2), 0.77);
        let v = Vec3::new(1.0, 2.0, 3.0);
        let back = q.conj().rotate(q.rotate(v));
        assert!((back - v).norm() < 1e-5);
    }
}
