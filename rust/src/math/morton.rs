//! Morton (Z-order) codes. Two users:
//!
//! * 2D codes order image tiles so the Load Distribution Unit hands
//!   spatially adjacent tiles to the same rasterization block, improving
//!   Gaussian-fetch locality (Sec. V-B);
//! * 3D codes key the spatial cells of the scene-sharding subsystem
//!   (`crate::shard`): Gaussians sorted by the Morton code of their grid
//!   cell land in contiguous shards, so a shard is a compact spatial
//!   region and whole-shard frustum culling stays tight.

/// Interleave the low 16 bits of x and y: (x,y) → 32-bit Morton code.
#[inline]
pub fn morton_encode2(x: u32, y: u32) -> u32 {
    part1by1(x) | (part1by1(y) << 1)
}

/// Inverse of [`morton_encode2`].
#[inline]
pub fn morton_decode2(code: u32) -> (u32, u32) {
    (compact1by1(code), compact1by1(code >> 1))
}

#[inline]
fn part1by1(mut v: u32) -> u32 {
    v &= 0x0000ffff;
    v = (v | (v << 8)) & 0x00ff00ff;
    v = (v | (v << 4)) & 0x0f0f0f0f;
    v = (v | (v << 2)) & 0x33333333;
    v = (v | (v << 1)) & 0x55555555;
    v
}

#[inline]
fn compact1by1(mut v: u32) -> u32 {
    v &= 0x55555555;
    v = (v | (v >> 1)) & 0x33333333;
    v = (v | (v >> 2)) & 0x0f0f0f0f;
    v = (v | (v >> 4)) & 0x00ff00ff;
    v = (v | (v >> 8)) & 0x0000ffff;
    v
}

/// Interleave the low 21 bits of x, y and z: (x,y,z) → 63-bit Morton code.
/// Shard cell keys: sorting Gaussians by this code gives the space-filling
/// order the partitioner chunks into shards.
#[inline]
pub fn morton_encode3(x: u32, y: u32, z: u32) -> u64 {
    part1by2(x) | (part1by2(y) << 1) | (part1by2(z) << 2)
}

/// Inverse of [`morton_encode3`].
#[inline]
pub fn morton_decode3(code: u64) -> (u32, u32, u32) {
    (
        compact1by2(code),
        compact1by2(code >> 1),
        compact1by2(code >> 2),
    )
}

#[inline]
fn part1by2(v: u32) -> u64 {
    let mut v = (v & 0x1f_ffff) as u64;
    v = (v | (v << 32)) & 0x1f00000000ffff;
    v = (v | (v << 16)) & 0x1f0000ff0000ff;
    v = (v | (v << 8)) & 0x100f00f00f00f00f;
    v = (v | (v << 4)) & 0x10c30c30c30c30c3;
    v = (v | (v << 2)) & 0x1249249249249249;
    v
}

#[inline]
fn compact1by2(mut v: u64) -> u32 {
    v &= 0x1249249249249249;
    v = (v | (v >> 2)) & 0x10c30c30c30c30c3;
    v = (v | (v >> 4)) & 0x100f00f00f00f00f;
    v = (v | (v >> 8)) & 0x1f0000ff0000ff;
    v = (v | (v >> 16)) & 0x1f00000000ffff;
    v = (v | (v >> 32)) & 0x1f_ffff;
    v as u32
}

/// Tile indices of a grid (w×h tiles) sorted in Morton order.
pub fn morton_order(w: usize, h: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..w * h).collect();
    idx.sort_by_key(|&i| morton_encode2((i % w) as u32, (i / w) as u32));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn known_codes() {
        assert_eq!(morton_encode2(0, 0), 0);
        assert_eq!(morton_encode2(1, 0), 1);
        assert_eq!(morton_encode2(0, 1), 2);
        assert_eq!(morton_encode2(1, 1), 3);
        assert_eq!(morton_encode2(2, 0), 4);
        assert_eq!(morton_encode2(7, 7), 0b111111);
    }

    #[test]
    fn encode_decode_bijection() {
        check("morton roundtrip", 1024, |rng| {
            let x = (rng.next_u64() & 0xffff) as u32;
            let y = (rng.next_u64() & 0xffff) as u32;
            assert_eq!(morton_decode2(morton_encode2(x, y)), (x, y));
        });
    }

    #[test]
    fn known_codes_3d() {
        assert_eq!(morton_encode3(0, 0, 0), 0);
        assert_eq!(morton_encode3(1, 0, 0), 0b001);
        assert_eq!(morton_encode3(0, 1, 0), 0b010);
        assert_eq!(morton_encode3(0, 0, 1), 0b100);
        assert_eq!(morton_encode3(1, 1, 1), 0b111);
        assert_eq!(morton_encode3(2, 0, 0), 0b001000);
        assert_eq!(morton_encode3(7, 7, 7), 0o777);
    }

    #[test]
    fn encode3_decode3_bijection() {
        check("morton3 roundtrip", 1024, |rng| {
            let x = (rng.next_u64() & 0x1f_ffff) as u32;
            let y = (rng.next_u64() & 0x1f_ffff) as u32;
            let z = (rng.next_u64() & 0x1f_ffff) as u32;
            assert_eq!(morton_decode3(morton_encode3(x, y, z)), (x, y, z));
        });
        // Full 21-bit corners.
        let m = 0x1f_ffff;
        assert_eq!(morton_decode3(morton_encode3(m, m, m)), (m, m, m));
    }

    #[test]
    fn encode3_orders_octants_before_cells() {
        // Z-order property: every cell of the low octant precedes every
        // cell of the high octant (the partitioner depends on this to get
        // spatially compact chunks).
        for (lo, hi) in [((3, 3, 3), (4, 0, 0)), ((7, 7, 7), (8, 8, 8))] {
            assert!(
                morton_encode3(lo.0, lo.1, lo.2) < morton_encode3(hi.0, hi.1, hi.2),
                "{lo:?} !< {hi:?}"
            );
        }
    }

    #[test]
    fn encode3_locality_better_than_row_major() {
        // Consecutive Morton codes should map to nearby cells on average.
        let g = 8u32;
        let mut cells: Vec<(u32, u32, u32)> = Vec::new();
        for x in 0..g {
            for y in 0..g {
                for z in 0..g {
                    cells.push((x, y, z));
                }
            }
        }
        cells.sort_by_key(|&(x, y, z)| morton_encode3(x, y, z));
        let dist = |a: (u32, u32, u32), b: (u32, u32, u32)| {
            (a.0 as i64 - b.0 as i64).abs()
                + (a.1 as i64 - b.1 as i64).abs()
                + (a.2 as i64 - b.2 as i64).abs()
        };
        let total: i64 = cells.windows(2).map(|w| dist(w[0], w[1])).sum();
        let avg = total as f64 / (cells.len() - 1) as f64;
        assert!(avg < 3.0, "morton3 locality too poor: {avg}");
    }

    #[test]
    fn order_is_permutation() {
        let ord = morton_order(5, 3);
        let mut sorted = ord.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..15).collect::<Vec<_>>());
    }

    #[test]
    fn z_pattern_on_2x2() {
        // Z-order within a 2x2 block: (0,0), (1,0), (0,1), (1,1).
        assert_eq!(morton_order(2, 2), vec![0, 1, 2, 3]);
    }

    #[test]
    fn locality_better_than_row_major() {
        // Mean manhattan distance between consecutive tiles should be lower
        // in Morton order than the worst-case wrap of row-major on a wide
        // grid — a sanity check of the locality argument in Sec. V-B.
        let (w, h) = (16, 16);
        let dist = |a: usize, b: usize| {
            let (ax, ay) = ((a % w) as i64, (a / w) as i64);
            let (bx, by) = ((b % w) as i64, (b / w) as i64);
            ((ax - bx).abs() + (ay - by).abs()) as f64
        };
        let morton = morton_order(w, h);
        let m_avg: f64 = morton.windows(2).map(|p| dist(p[0], p[1])).sum::<f64>()
            / (morton.len() - 1) as f64;
        assert!(m_avg < 2.5, "morton locality too poor: {m_avg}");
    }
}
