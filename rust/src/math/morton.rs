//! Morton (Z-order) codes for tile coordinates. The Load Distribution Unit
//! traverses tiles in Morton order so spatially adjacent tiles land in the
//! same rasterization block, improving Gaussian-fetch locality (Sec. V-B).

/// Interleave the low 16 bits of x and y: (x,y) → 32-bit Morton code.
#[inline]
pub fn morton_encode2(x: u32, y: u32) -> u32 {
    part1by1(x) | (part1by1(y) << 1)
}

/// Inverse of [`morton_encode2`].
#[inline]
pub fn morton_decode2(code: u32) -> (u32, u32) {
    (compact1by1(code), compact1by1(code >> 1))
}

#[inline]
fn part1by1(mut v: u32) -> u32 {
    v &= 0x0000ffff;
    v = (v | (v << 8)) & 0x00ff00ff;
    v = (v | (v << 4)) & 0x0f0f0f0f;
    v = (v | (v << 2)) & 0x33333333;
    v = (v | (v << 1)) & 0x55555555;
    v
}

#[inline]
fn compact1by1(mut v: u32) -> u32 {
    v &= 0x55555555;
    v = (v | (v >> 1)) & 0x33333333;
    v = (v | (v >> 2)) & 0x0f0f0f0f;
    v = (v | (v >> 4)) & 0x00ff00ff;
    v = (v | (v >> 8)) & 0x0000ffff;
    v
}

/// Tile indices of a grid (w×h tiles) sorted in Morton order.
pub fn morton_order(w: usize, h: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..w * h).collect();
    idx.sort_by_key(|&i| morton_encode2((i % w) as u32, (i / w) as u32));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn known_codes() {
        assert_eq!(morton_encode2(0, 0), 0);
        assert_eq!(morton_encode2(1, 0), 1);
        assert_eq!(morton_encode2(0, 1), 2);
        assert_eq!(morton_encode2(1, 1), 3);
        assert_eq!(morton_encode2(2, 0), 4);
        assert_eq!(morton_encode2(7, 7), 0b111111);
    }

    #[test]
    fn encode_decode_bijection() {
        check("morton roundtrip", 1024, |rng| {
            let x = (rng.next_u64() & 0xffff) as u32;
            let y = (rng.next_u64() & 0xffff) as u32;
            assert_eq!(morton_decode2(morton_encode2(x, y)), (x, y));
        });
    }

    #[test]
    fn order_is_permutation() {
        let ord = morton_order(5, 3);
        let mut sorted = ord.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..15).collect::<Vec<_>>());
    }

    #[test]
    fn z_pattern_on_2x2() {
        // Z-order within a 2x2 block: (0,0), (1,0), (0,1), (1,1).
        assert_eq!(morton_order(2, 2), vec![0, 1, 2, 3]);
    }

    #[test]
    fn locality_better_than_row_major() {
        // Mean manhattan distance between consecutive tiles should be lower
        // in Morton order than the worst-case wrap of row-major on a wide
        // grid — a sanity check of the locality argument in Sec. V-B.
        let (w, h) = (16, 16);
        let dist = |a: usize, b: usize| {
            let (ax, ay) = ((a % w) as i64, (a / w) as i64);
            let (bx, by) = ((b % w) as i64, (b / w) as i64);
            ((ax - bx).abs() + (ay - by).abs()) as f64
        };
        let morton = morton_order(w, h);
        let m_avg: f64 = morton.windows(2).map(|p| dist(p[0], p[1])).sum::<f64>()
            / (morton.len() - 1) as f64;
        assert!(m_avg < 2.5, "morton locality too poor: {m_avg}");
    }
}
