"""L2: the jax compute graphs AOT-lowered into ``artifacts/`` and executed
from rust via PJRT (never imported at runtime).

Three graphs:

* :func:`rasterize_tiles` — re-exported from the L1 Pallas kernel; the
  request-path hot spot (sparse tile re-rendering).
* :func:`project_gaussians` — preprocessing math for a fixed-size chunk of
  Gaussians: world->camera, EWA covariance projection, conic, degree-1 SH
  color. Mirrors rust/src/render/preprocess.rs exactly (same dilation,
  Jacobian clamping and SH constants) so the two backends agree numerically.
* :func:`warp_frame` — viewpoint transformation (Algo. 1 lines 2-4):
  back-project, rigid transform, forward splat with a z-buffer, expressed
  with scatter-min so it lowers to a single fused HLO module.
"""

import jax
import jax.numpy as jnp

from .kernels.rasterize import rasterize_tiles  # noqa: F401  (re-export)

COV_DILATION = 0.3
# Real SH constants, degree 0/1 (match rust/src/math/sh.rs).
SH_C0 = 0.28209479
SH_C1 = 0.48860251


def quat_to_mat(q):
    """(N,4) wxyz unit quaternions -> (N,3,3) rotation matrices."""
    w, x, y, z = q[:, 0], q[:, 1], q[:, 2], q[:, 3]
    return jnp.stack(
        [
            jnp.stack([1 - 2 * (y * y + z * z), 2 * (x * y - w * z), 2 * (x * z + w * y)], -1),
            jnp.stack([2 * (x * y + w * z), 1 - 2 * (x * x + z * z), 2 * (y * z - w * x)], -1),
            jnp.stack([2 * (x * z - w * y), 2 * (y * z + w * x), 1 - 2 * (x * x + y * y)], -1),
        ],
        -2,
    )


def project_gaussians(positions, scales, rotations, opacities, sh, w2c, intr, cam_pos):
    """Project a fixed-size chunk of Gaussians.

    Args:
      positions: (N, 3), scales: (N, 3), rotations: (N, 4) wxyz,
      opacities: (N,), sh: (N, 12) degree-1 coeffs (coeff-major, rgb-minor),
      w2c: (4, 4) world->camera, intr: (6,) = [fx, fy, cx, cy, near, far],
      cam_pos: (3,) camera position in world space.

    Returns (means2d (N,2), cov2d (N,3), conic (N,3), depth (N,), color
    (N,3), visible (N,) in {0,1}).
    """
    fx, fy, cx, cy, near, far = (intr[i] for i in range(6))
    rot = w2c[:3, :3]
    p_cam = positions @ rot.T + w2c[:3, 3][None, :]
    z = p_cam[:, 2]
    visible = (z >= near) & (z <= far)

    zs = jnp.maximum(z, 1e-6)
    mean_x = fx * p_cam[:, 0] / zs + cx
    mean_y = fy * p_cam[:, 1] / zs + cy
    means2d = jnp.stack([mean_x, mean_y], -1)

    # World covariance R S S^T R^T.
    r = quat_to_mat(rotations)
    rs = r * scales[:, None, :]
    cov3d = rs @ jnp.swapaxes(rs, 1, 2)

    # EWA Jacobian with frustum-edge clamping (2*cx = width).
    lim_x = 1.3 * cx / fx
    lim_y = 1.3 * cy / fy
    tx = jnp.clip(p_cam[:, 0] / zs, -lim_x, lim_x) * zs
    ty = jnp.clip(p_cam[:, 1] / zs, -lim_y, lim_y) * zs
    zero = jnp.zeros_like(zs)
    j = jnp.stack(
        [
            jnp.stack([fx / zs, zero, -fx * tx / (zs * zs)], -1),
            jnp.stack([zero, fy / zs, -fy * ty / (zs * zs)], -1),
            jnp.stack([zero, zero, zero], -1),
        ],
        -2,
    )  # (N,3,3)
    t = j @ rot[None, :, :]
    cov2 = t @ cov3d @ jnp.swapaxes(t, 1, 2)
    a = cov2[:, 0, 0] + COV_DILATION
    bb = cov2[:, 0, 1]
    c = cov2[:, 1, 1] + COV_DILATION
    det = a * c - bb * bb
    visible = visible & (det > 1e-12)
    inv = 1.0 / jnp.where(det > 1e-12, det, 1.0)
    conic = jnp.stack([c * inv, -bb * inv, a * inv], -1)

    # Degree-1 SH color along the view direction.
    d = positions - cam_pos[None, :]
    d = d / jnp.maximum(jnp.linalg.norm(d, axis=-1, keepdims=True), 1e-9)
    basis = jnp.stack(
        [
            jnp.full_like(d[:, 0], SH_C0),
            -SH_C1 * d[:, 1],
            SH_C1 * d[:, 2],
            -SH_C1 * d[:, 0],
        ],
        -1,
    )  # (N,4)
    coeffs = sh.reshape(sh.shape[0], 4, 3)
    color = jnp.einsum("nc,ncr->nr", basis, coeffs) + 0.5
    color = jnp.maximum(color, 0.0)

    return (
        means2d,
        jnp.stack([a, bb, c], -1),
        conic,
        z,
        color,
        visible.astype(jnp.float32),
    )


def warp_frame(rgb, depth, valid, ref2tgt, intr):
    """Forward-splat reprojection with a z-buffer (Algo. 1 lines 2-4).

    Args:
      rgb: (H, W, 3), depth: (H, W), valid: (H, W) in {0,1},
      ref2tgt: (4, 4) ref-camera -> tgt-camera rigid transform,
      intr: (6,) = [fx, fy, cx, cy, near, far].

    Returns (rgb_t (H,W,3), depth_t (H,W), filled (H,W) in {0,1}).
    Only `valid` pixels are splatted (background/mask handling lives in the
    rust coordinator, which owns the policy).
    """
    h, w = depth.shape
    fx, fy, cx, cy, near, _far = (intr[i] for i in range(6))
    ys, xs = jnp.meshgrid(jnp.arange(h, dtype=jnp.float32), jnp.arange(w, dtype=jnp.float32), indexing="ij")
    px = xs + 0.5
    py = ys + 0.5
    x_cam = (px - cx) / fx * depth
    y_cam = (py - cy) / fy * depth
    p = jnp.stack([x_cam, y_cam, depth, jnp.ones_like(depth)], -1)  # (H,W,4)
    pt = jnp.einsum("ij,hwj->hwi", ref2tgt, p)
    zt = pt[..., 2]
    ok = (valid > 0.5) & (zt > near)
    ut = fx * pt[..., 0] / jnp.maximum(zt, 1e-6) + cx
    vt = fy * pt[..., 1] / jnp.maximum(zt, 1e-6) + cy
    txi = jnp.floor(ut).astype(jnp.int32)
    tyi = jnp.floor(vt).astype(jnp.int32)
    inb = ok & (txi >= 0) & (tyi >= 0) & (txi < w) & (tyi < h)
    flat_idx = jnp.where(inb, tyi * w + txi, 0)

    big = jnp.float32(1e30)
    z_src = jnp.where(inb, zt, big).reshape(-1)
    zmin = jnp.full((h * w,), big, jnp.float32).at[flat_idx.reshape(-1)].min(
        z_src, mode="drop"
    )
    # A source pixel wins if its z equals the buffered min at its target.
    winner = inb & (zt <= zmin[flat_idx] + 0.0)
    rgb_t = (
        jnp.zeros((h * w, 3), jnp.float32)
        .at[flat_idx.reshape(-1)]
        .max(
            jnp.where(winner.reshape(-1, 1), rgb.reshape(-1, 3), -1.0),
            mode="drop",
        )
    )
    rgb_t = jnp.maximum(rgb_t, 0.0).reshape(h, w, 3)
    filled = (zmin < big).astype(jnp.float32).reshape(h, w)
    depth_t = jnp.where(zmin < big, zmin, jnp.inf).reshape(h, w)
    return rgb_t, depth_t, filled
