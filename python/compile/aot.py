"""AOT lowering: jax -> HLO *text* -> artifacts/ for the rust runtime.

HLO text (NOT serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids that the `xla` crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md and gen_hlo.py).

Usage:  cd python && python -m compile.aot --outdir ../artifacts
Emits one .hlo.txt per graph variant plus manifest.json describing shapes,
which rust/src/runtime/artifacts.rs consumes.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# Tile-batch variants compiled for the sparse-rendering hot path: the
# runtime picks the smallest K that fits a tile's (DPES-culled) list.
RASTERIZE_VARIANTS = [(16, 64), (16, 256), (16, 1024)]
PROJECT_CHUNK = 4096


def to_hlo_text(fn, *args) -> str:
    lowered = jax.jit(fn).lower(*args)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def build_all(outdir: str, width: int, height: int) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"version": 1, "tile": 16, "artifacts": {}}

    def emit(name, fn, *args, meta=None):
        text = to_hlo_text(fn, *args)
        path = f"{name}.hlo.txt"
        with open(os.path.join(outdir, path), "w") as f:
            f.write(text)
        entry = {"file": path}
        entry.update(meta or {})
        manifest["artifacts"][name] = entry
        print(f"  {name}: {len(text)} chars")

    for b, k in RASTERIZE_VARIANTS:
        emit(
            f"rasterize_b{b}_k{k}",
            model.rasterize_tiles,
            f32(b, k, 2),
            f32(b, k, 3),
            f32(b, k, 3),
            f32(b, k),
            f32(b, k),
            f32(b, k),
            f32(b, 2),
            f32(3),
            meta={"kind": "rasterize", "batch": b, "k": k},
        )

    emit(
        f"project_n{PROJECT_CHUNK}",
        model.project_gaussians,
        f32(PROJECT_CHUNK, 3),
        f32(PROJECT_CHUNK, 3),
        f32(PROJECT_CHUNK, 4),
        f32(PROJECT_CHUNK),
        f32(PROJECT_CHUNK, 12),
        f32(4, 4),
        f32(6),
        f32(3),
        meta={"kind": "project", "chunk": PROJECT_CHUNK},
    )

    emit(
        f"warp_{width}x{height}",
        model.warp_frame,
        f32(height, width, 3),
        f32(height, width),
        f32(height, width),
        f32(4, 4),
        f32(6),
        meta={"kind": "warp", "width": width, "height": height},
    )

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--width", type=int, default=256)
    ap.add_argument("--height", type=int, default=192)
    args = ap.parse_args()
    print(f"lowering AOT artifacts into {args.outdir}")
    build_all(args.outdir, args.width, args.height)
    print("done")


if __name__ == "__main__":
    main()
