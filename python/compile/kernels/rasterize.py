"""L1: Pallas tile-rasterization kernel — the 3DGS compute hot-spot.

One grid step rasterizes one 16x16 tile: a ``fori_loop`` walks the tile's
depth-sorted (padded) Gaussian list, evaluating Eq. 1 of the paper on the
whole 256-pixel tile at once and alpha-blending per Eq. 2 with per-pixel
early stopping (lane-masked: saturated pixels stop accumulating).

Hardware adaptation (DESIGN.md section "Hardware adaptation"): the paper's
CUDA kernel gives each pixel a thread in a 16x16 block; on a TPU-shaped
machine the tile *is* the vector register block, resident in VMEM, and the
Gaussian list streams through it. ``interpret=True`` everywhere — the CPU
PJRT plugin cannot execute Mosaic custom-calls (see /opt/xla-example
README); correctness is validated against ``ref.py`` and the rust native
rasterizer.

Numeric contract (must match rust/src/render/rasterize.rs bit-for-bit up to
float assoc.):
  * support cutoff  e = 0.5 * d^T conic d in [0, 4.5]
  * alpha = min(opacity * exp(-e), 0.999), contributes when alpha >= 1/255
  * per-pixel stop at transmittance < 1e-4
  * trunc depth = depth at the crossing Gaussian, else depth of the last
    valid Gaussian in the list
  * background blended under residual transmittance
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE = 16
ALPHA_THRESHOLD = 1.0 / 255.0
ALPHA_CAP = 0.999
T_EPS = 1e-4
E_MAX = 4.5
VALID_ALPHA = 0.5
INVALID_DEPTH = jnp.inf


def _tile_pixel_coords(origin):
    """Pixel-center coordinates of a tile given its (x0, y0) origin."""
    ix = jax.lax.broadcasted_iota(jnp.float32, (TILE, TILE), 1)
    iy = jax.lax.broadcasted_iota(jnp.float32, (TILE, TILE), 0)
    px = origin[0] + ix + 0.5
    py = origin[1] + iy + 0.5
    return px, py


def _rasterize_tile_kernel(
    means_ref,
    conics_ref,
    colors_ref,
    opac_ref,
    depths_ref,
    valid_ref,
    origin_ref,
    bg_ref,
    rgb_ref,
    alpha_ref,
    depth_ref,
    trunc_ref,
):
    """Kernel body: one tile (block shapes carry a leading 1)."""
    means = means_ref[0]  # (K, 2)
    conics = conics_ref[0]  # (K, 3)
    colors = colors_ref[0]  # (K, 3)
    opac = opac_ref[0]  # (K,)
    depths = depths_ref[0]  # (K,)
    valid = valid_ref[0]  # (K,) float 0/1
    origin = origin_ref[0]  # (2,)
    bg = bg_ref[...]  # (3,)
    k_total = means.shape[0]

    px, py = _tile_pixel_coords(origin)

    def body(k, carry):
        trans, rgb, dacc, wacc, trunc, last_depth = carry
        mean = jax.lax.dynamic_slice_in_dim(means, k, 1, 0)[0]
        conic = jax.lax.dynamic_slice_in_dim(conics, k, 1, 0)[0]
        color = jax.lax.dynamic_slice_in_dim(colors, k, 1, 0)[0]
        o = jax.lax.dynamic_slice_in_dim(opac, k, 1, 0)[0]
        z = jax.lax.dynamic_slice_in_dim(depths, k, 1, 0)[0]
        v = jax.lax.dynamic_slice_in_dim(valid, k, 1, 0)[0]

        dx = px - mean[0]
        dy = py - mean[1]
        e = 0.5 * (conic[0] * dx * dx + 2.0 * conic[1] * dx * dy + conic[2] * dy * dy)
        in_support = (e >= 0.0) & (e <= E_MAX)
        alpha = jnp.minimum(o * jnp.exp(-e), ALPHA_CAP)
        alpha = jnp.where(in_support & (alpha >= ALPHA_THRESHOLD) & (v > 0.5), alpha, 0.0)

        active = trans >= T_EPS
        w = jnp.where(active, alpha * trans, 0.0)  # (16,16)
        rgb = rgb + w[..., None] * color[None, None, :]
        dacc = dacc + w * z
        wacc = wacc + w
        new_trans = jnp.where(active, trans * (1.0 - alpha), trans)
        crossed = active & (new_trans < T_EPS)
        trunc = jnp.where(crossed, z, trunc)
        last_depth = jnp.where(v > 0.5, z, last_depth)
        return new_trans, rgb, dacc, wacc, trunc, last_depth

    init = (
        jnp.ones((TILE, TILE), jnp.float32),
        jnp.zeros((TILE, TILE, 3), jnp.float32),
        jnp.zeros((TILE, TILE), jnp.float32),
        jnp.zeros((TILE, TILE), jnp.float32),
        jnp.full((TILE, TILE), INVALID_DEPTH, jnp.float32),
        jnp.float32(INVALID_DEPTH),
    )
    trans, rgb, dacc, wacc, trunc, last_depth = jax.lax.fori_loop(
        0, k_total, body, init
    )

    alpha_out = 1.0 - trans
    rgb = rgb + trans[..., None] * bg[None, None, :]
    depth_out = jnp.where(wacc > 1e-6, dacc / jnp.maximum(wacc, 1e-12), INVALID_DEPTH)
    # Pixels that never crossed: truncation = last valid Gaussian's depth
    # (matches the rust rasterizer when the whole list is traversed).
    trunc_out = jnp.where(jnp.isinf(trunc), last_depth, trunc)

    rgb_ref[0] = rgb
    alpha_ref[0] = alpha_out
    depth_ref[0] = depth_out
    trunc_ref[0] = trunc_out


@functools.partial(jax.jit, static_argnames=())
def rasterize_tiles(means, conics, colors, opacities, depths, valid, origins, bg):
    """Rasterize a batch of B tiles, each with K (padded) sorted Gaussians.

    Args:
      means:     (B, K, 2) float32 — projected centers (pixels).
      conics:    (B, K, 3) float32 — inverse 2D covariance (a, b, c).
      colors:    (B, K, 3) float32.
      opacities: (B, K)    float32.
      depths:    (B, K)    float32 — camera-space z, sorted ascending.
      valid:     (B, K)    float32 — 1.0 for real entries, 0.0 for padding.
      origins:   (B, 2)    float32 — tile pixel origins (x0, y0).
      bg:        (3,)      float32 — background color.

    Returns:
      rgb (B,16,16,3), alpha (B,16,16), depth (B,16,16), trunc (B,16,16).
    """
    b, k = means.shape[0], means.shape[1]
    grid = (b,)
    row = lambda i: (i, 0, 0)  # noqa: E731
    row2 = lambda i: (i, 0)  # noqa: E731
    return pl.pallas_call(
        _rasterize_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, k, 2), row),
            pl.BlockSpec((1, k, 3), row),
            pl.BlockSpec((1, k, 3), row),
            pl.BlockSpec((1, k), row2),
            pl.BlockSpec((1, k), row2),
            pl.BlockSpec((1, k), row2),
            pl.BlockSpec((1, 2), row2),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((1, TILE, TILE, 3), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, TILE, TILE), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, TILE, TILE), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, TILE, TILE), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, TILE, TILE, 3), jnp.float32),
            jax.ShapeDtypeStruct((b, TILE, TILE), jnp.float32),
            jax.ShapeDtypeStruct((b, TILE, TILE), jnp.float32),
            jax.ShapeDtypeStruct((b, TILE, TILE), jnp.float32),
        ],
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(means, conics, colors, opacities, depths, valid, origins, bg)
