"""Pure-jnp oracle for the Pallas rasterization kernel.

Deliberately a *different formulation* from the kernel's sequential loop:
the whole (B, K, 16, 16) alpha tensor is materialized and blending uses the
closed-form exclusive cumulative product

    T_k = prod_{j<k} (1 - alpha_j),   contribution_k = alpha_k * T_k,

with early stopping expressed as "contributions freeze once T drops below
1e-4" (the exclusive product is exact up to and including the crossing
Gaussian, which is exactly the set the sequential loop blends).
"""

import jax.numpy as jnp

from .rasterize import (
    ALPHA_CAP,
    ALPHA_THRESHOLD,
    E_MAX,
    INVALID_DEPTH,
    T_EPS,
    TILE,
)


def rasterize_reference(means, conics, colors, opacities, depths, valid, origins, bg):
    """Reference implementation; same signature/returns as rasterize_tiles."""
    b, k = means.shape[0], means.shape[1]
    ix = jnp.arange(TILE, dtype=jnp.float32)
    px = origins[:, None, None, 0] + ix[None, None, :] + 0.5  # (B,1,16)->(B,16,16) via bcast below
    py = origins[:, None, None, 1] + ix[None, :, None] + 0.5
    px = jnp.broadcast_to(px, (b, TILE, TILE))
    py = jnp.broadcast_to(py, (b, TILE, TILE))

    dx = px[:, None] - means[:, :, 0][:, :, None, None]  # (B,K,16,16)
    dy = py[:, None] - means[:, :, 1][:, :, None, None]
    ca = conics[:, :, 0][:, :, None, None]
    cb = conics[:, :, 1][:, :, None, None]
    cc = conics[:, :, 2][:, :, None, None]
    e = 0.5 * (ca * dx * dx + 2.0 * cb * dx * dy + cc * dy * dy)
    in_support = (e >= 0.0) & (e <= E_MAX)
    alpha = jnp.minimum(opacities[:, :, None, None] * jnp.exp(-e), ALPHA_CAP)
    alpha = jnp.where(
        in_support & (alpha >= ALPHA_THRESHOLD) & (valid[:, :, None, None] > 0.5),
        alpha,
        0.0,
    )

    # Exclusive cumulative transmittance.
    one_minus = 1.0 - alpha
    cum = jnp.cumprod(one_minus, axis=1)  # inclusive
    t_excl = jnp.concatenate(
        [jnp.ones_like(cum[:, :1]), cum[:, :-1]], axis=1
    )  # (B,K,16,16)
    active = t_excl >= T_EPS
    w = jnp.where(active, alpha * t_excl, 0.0)

    rgb = jnp.einsum("bkxy,bkc->bxyc", w, colors)
    z = depths[:, :, None, None]
    dacc = jnp.sum(w * z, axis=1)
    wacc = jnp.sum(w, axis=1)

    # Final transmittance freezes at the crossing Gaussian.
    t_incl = jnp.where(active, cum, 0.0)  # value after each processed k
    crossed = active & (cum < T_EPS)  # (B,K,16,16)
    any_cross = jnp.any(crossed, axis=1)
    # Transmittance after the last *processed* Gaussian:
    processed = active  # every active k was processed
    last_processed_t = jnp.where(
        jnp.any(processed, axis=1),
        # t after the last processed index = min over processed of t_incl
        jnp.min(jnp.where(processed, t_incl, jnp.inf), axis=1),
        1.0,
    )
    trans = jnp.where(any_cross, last_processed_t, last_processed_t)
    alpha_out = 1.0 - trans

    rgb = rgb + trans[..., None] * bg[None, None, None, :]
    depth_out = jnp.where(wacc > 1e-6, dacc / jnp.maximum(wacc, 1e-12), INVALID_DEPTH)

    # Truncation depth: depth of the crossing Gaussian, else the last valid
    # Gaussian's depth (the whole list was traversed).
    cross_idx = jnp.argmax(crossed, axis=1)  # first True (0 if none)
    trunc_cross = jnp.take_along_axis(
        jnp.broadcast_to(z, crossed.shape), cross_idx[:, None], axis=1
    )[:, 0]
    any_valid = jnp.any(valid > 0.5, axis=1)
    last_valid_idx = (k - 1) - jnp.argmax(jnp.flip(valid > 0.5, axis=1), axis=1)
    last_depth = jnp.take_along_axis(depths, last_valid_idx[:, None], axis=1)[:, 0]
    last_depth = jnp.where(any_valid, last_depth, INVALID_DEPTH)
    trunc_out = jnp.where(
        any_cross, trunc_cross, last_depth[:, None, None] * jnp.ones((1, TILE, TILE))
    )
    return rgb, alpha_out, depth_out, trunc_out
