"""L1 correctness: Pallas kernel vs the pure-jnp oracle.

This is the core correctness signal of the compile path — the same kernel
body is what the AOT artifacts embed, so agreement here + the rust-side
parity test closes the loop.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.rasterize import (
    ALPHA_THRESHOLD,
    T_EPS,
    TILE,
    rasterize_tiles,
)
from compile.kernels.ref import rasterize_reference


def make_inputs(rng, b=2, k=8, opacity_range=(0.05, 0.99), spread=24.0):
    """Random but well-conditioned tile batches."""
    origins = rng.uniform(0, 64, size=(b, 2)).astype(np.float32) // 16 * 16
    means = (
        origins[:, None, :]
        + rng.uniform(-spread * 0.25, TILE + spread * 0.25, size=(b, k, 2))
    ).astype(np.float32)
    # Random SPD conics via random 2x2 A: conic = A A^T scaled.
    a = rng.normal(size=(b, k, 2, 2)).astype(np.float32)
    spd = a @ np.swapaxes(a, -1, -2) + 0.05 * np.eye(2, dtype=np.float32)
    # Normalize so splats are a few pixels wide: conic ~ inverse cov.
    cov = spd * rng.uniform(2.0, 40.0, size=(b, k, 1, 1)).astype(np.float32)
    det = cov[..., 0, 0] * cov[..., 1, 1] - cov[..., 0, 1] ** 2
    conics = np.stack(
        [cov[..., 1, 1] / det, -cov[..., 0, 1] / det, cov[..., 0, 0] / det], -1
    ).astype(np.float32)
    colors = rng.uniform(0, 1, size=(b, k, 3)).astype(np.float32)
    opac = rng.uniform(*opacity_range, size=(b, k)).astype(np.float32)
    depths = np.sort(rng.uniform(0.5, 20.0, size=(b, k)).astype(np.float32), axis=1)
    valid = (rng.uniform(size=(b, k)) > 0.2).astype(np.float32)
    bg = rng.uniform(0, 1, size=(3,)).astype(np.float32)
    return means, conics, colors, opac, depths, valid, origins, bg


def run_both(args):
    out_k = rasterize_tiles(*[jnp.asarray(x) for x in args])
    out_r = rasterize_reference(*[jnp.asarray(x) for x in args])
    return [np.asarray(x) for x in out_k], [np.asarray(x) for x in out_r]


def assert_match(out_k, out_r, tol=1e-5):
    names = ["rgb", "alpha", "depth", "trunc"]
    for name, a, b in zip(names, out_k, out_r):
        finite = np.isfinite(b)
        np.testing.assert_array_equal(np.isfinite(a), finite, err_msg=name)
        np.testing.assert_allclose(
            a[finite], b[finite], rtol=1e-4, atol=tol, err_msg=name
        )


class TestKernelVsOracle:
    def test_basic_agreement(self):
        rng = np.random.default_rng(0)
        args = make_inputs(rng, b=4, k=16)
        out_k, out_r = run_both(args)
        assert_match(out_k, out_r)

    def test_empty_tiles_render_background(self):
        rng = np.random.default_rng(1)
        args = list(make_inputs(rng, b=2, k=4))
        args[5] = np.zeros_like(args[5])  # all invalid
        out_k, out_r = run_both(args)
        assert_match(out_k, out_r)
        bg = args[7]
        np.testing.assert_allclose(out_k[0][0, 0, 0], bg, atol=1e-6)
        assert out_k[1].max() == 0.0  # alpha
        assert np.isinf(out_k[2]).all()  # depth invalid

    def test_opaque_stack_early_stops(self):
        rng = np.random.default_rng(2)
        b, k = 1, 32
        origins = np.zeros((b, 2), np.float32)
        means = np.tile(np.array([[8.0, 8.0]], np.float32), (k, 1))[None]
        conics = np.tile(np.array([[0.02, 0.0, 0.02]], np.float32), (k, 1))[None]
        colors = rng.uniform(0, 1, (b, k, 3)).astype(np.float32)
        opac = np.full((b, k), 0.95, np.float32)
        depths = np.linspace(1.0, 4.0, k, dtype=np.float32)[None]
        valid = np.ones((b, k), np.float32)
        bg = np.zeros(3, np.float32)
        args = (means, conics, colors, opac, depths, valid, origins, bg)
        out_k, out_r = run_both(args)
        assert_match(out_k, out_r)
        # Early stop fires within the first few gaussians at the tile
        # center (corners see lower alpha and stop later).
        assert out_k[3][0, 8, 8] < 1.5, out_k[3][0, 8, 8]
        assert out_k[3].max() < 4.0  # everyone stops before the list ends
        assert out_k[1].min() > 1.0 - T_EPS * 2

    def test_single_faint_gaussian_below_threshold(self):
        rng = np.random.default_rng(3)
        args = list(make_inputs(rng, b=1, k=1, opacity_range=(1e-4, ALPHA_THRESHOLD * 0.9)))
        out_k, out_r = run_both(args)
        assert_match(out_k, out_r)
        assert out_k[1].max() == 0.0

    def test_blending_formula_known_case(self):
        # Two flat gaussians at a pixel: C = a1 c1 + a2 (1-a1) c2 + T bg.
        b, k = 1, 2
        origins = np.zeros((b, 2), np.float32)
        means = np.array([[[8.0, 8.0], [8.0, 8.0]]], np.float32)
        conics = np.full((b, k, 3), 0.0, np.float32)
        conics[..., 0] = 1e-6
        conics[..., 2] = 1e-6  # ~flat over the tile
        colors = np.array([[[1.0, 0.0, 0.0], [0.0, 0.0, 1.0]]], np.float32)
        opac = np.array([[0.5, 0.8]], np.float32)
        depths = np.array([[1.0, 2.0]], np.float32)
        valid = np.ones((b, k), np.float32)
        bg = np.array([0.0, 1.0, 0.0], np.float32)
        out_k, _ = run_both((means, conics, colors, opac, depths, valid, origins, bg))
        c = out_k[0][0, 8, 8]
        np.testing.assert_allclose(c[0], 0.5, atol=1e-3)
        np.testing.assert_allclose(c[2], 0.8 * 0.5, atol=1e-3)
        np.testing.assert_allclose(c[1], 0.1, atol=1e-3)  # T=0.1 * green bg

    def test_padding_is_inert(self):
        rng = np.random.default_rng(4)
        args = list(make_inputs(rng, b=2, k=8))
        # Same inputs padded to k=32 with garbage in the invalid region.
        pad = 24
        padded = []
        for i, x in enumerate(args[:6]):
            g = rng.normal(size=(x.shape[0], pad) + x.shape[2:]).astype(np.float32)
            if i == 4:  # depths must stay sorted-ish; padding is masked anyway
                g = np.abs(g) + 100.0
            padded.append(np.concatenate([x, g], axis=1))
        padded[5][:, 8:] = 0.0  # valid=0 for padding
        out_small, _ = run_both(tuple(args))
        out_padded, _ = run_both(tuple(padded) + (args[6], args[7]))
        assert_match(out_padded, out_small)


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    b=st.integers(1, 4),
    k=st.integers(1, 24),
    lo=st.floats(0.01, 0.5),
)
def test_kernel_matches_oracle_fuzz(seed, b, k, lo):
    """Hypothesis sweep over batch sizes, list lengths and opacity ranges."""
    rng = np.random.default_rng(seed)
    args = make_inputs(rng, b=b, k=k, opacity_range=(lo, min(lo + 0.5, 0.99)))
    out_k, out_r = run_both(args)
    assert_match(out_k, out_r)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_transmittance_invariants_fuzz(seed):
    """alpha in [0,1]; depth finite iff something blended; rgb bounded."""
    rng = np.random.default_rng(seed)
    args = make_inputs(rng, b=2, k=12)
    out_k, _ = run_both(args)
    rgb, alpha, depth, trunc = out_k
    assert (alpha >= 0).all() and (alpha <= 1.0).all()
    assert (rgb >= -1e-6).all() and (rgb <= 2.0).all()
    blended = alpha > 1e-6
    assert np.isfinite(depth[blended]).all()
    assert (depth[blended] > 0).all()


def test_jit_compiles_once():
    """rasterize_tiles must be jit-stable (no per-call retrace explosions)."""
    rng = np.random.default_rng(7)
    args = make_inputs(rng, b=2, k=8)
    jargs = [jnp.asarray(x) for x in args]
    out1 = rasterize_tiles(*jargs)
    out2 = rasterize_tiles(*jargs)
    for a, b in zip(out1, out2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
