"""L2 graph tests: projection math vs a plain-numpy re-derivation, warp
round-trips, and AOT lowering producing parseable HLO text."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import aot, model


def intr6(fx=300.0, fy=300.0, cx=128.0, cy=96.0, near=0.05, far=1000.0):
    return np.array([fx, fy, cx, cy, near, far], np.float32)


class TestProject:
    def test_center_gaussian_projects_to_principal_point(self):
        n = 4
        pos = np.zeros((n, 3), np.float32)
        pos[:, 2] = 5.0
        scales = np.full((n, 3), 0.1, np.float32)
        rots = np.tile(np.array([1, 0, 0, 0], np.float32), (n, 1))
        opac = np.full((n,), 0.9, np.float32)
        sh = np.zeros((n, 12), np.float32)
        w2c = np.eye(4, dtype=np.float32)
        out = model.project_gaussians(
            *map(jnp.asarray, (pos, scales, rots, opac, sh, w2c, intr6(), np.zeros(3, np.float32)))
        )
        means2d, cov2d, conic, depth, color, visible = map(np.asarray, out)
        np.testing.assert_allclose(means2d[:, 0], 128.0, atol=1e-3)
        np.testing.assert_allclose(means2d[:, 1], 96.0, atol=1e-3)
        np.testing.assert_allclose(depth, 5.0, atol=1e-5)
        assert (visible == 1.0).all()
        # sigma_px^2 = (fx * s / z)^2 + dilation
        want = (300.0 * 0.1 / 5.0) ** 2 + model.COV_DILATION
        np.testing.assert_allclose(cov2d[:, 0], want, rtol=0.02)
        np.testing.assert_allclose(cov2d[:, 2], want, rtol=0.02)
        # conic = inverse
        np.testing.assert_allclose(conic[:, 0] * cov2d[:, 0], 1.0, rtol=0.05)
        # SH with zero coeffs -> 0.5 gray
        np.testing.assert_allclose(color, 0.5, atol=1e-6)

    def test_behind_camera_invisible(self):
        pos = np.array([[0, 0, -3.0], [0, 0, 3.0]], np.float32)
        scales = np.full((2, 3), 0.1, np.float32)
        rots = np.tile(np.array([1, 0, 0, 0], np.float32), (2, 1))
        out = model.project_gaussians(
            *map(
                jnp.asarray,
                (
                    pos,
                    scales,
                    rots,
                    np.full(2, 0.9, np.float32),
                    np.zeros((2, 12), np.float32),
                    np.eye(4, dtype=np.float32),
                    intr6(),
                    np.zeros(3, np.float32),
                ),
            )
        )
        visible = np.asarray(out[5])
        assert visible[0] == 0.0 and visible[1] == 1.0

    def test_sh_degree1_directionality(self):
        # A gaussian with only the -C1*x basis coefficient set: color must
        # differ between views from +x and -x.
        pos = np.array([[0, 0, 5.0]], np.float32)
        scales = np.full((1, 3), 0.1, np.float32)
        rots = np.array([[1, 0, 0, 0]], np.float32)
        sh = np.zeros((1, 12), np.float32)
        sh[0, 9] = 1.0  # coeff 3 (the -C1*x basis), red channel
        common = (
            scales,
            rots,
            np.full(1, 0.9, np.float32),
            sh,
            np.eye(4, dtype=np.float32),
            intr6(),
        )
        c_from_origin = np.asarray(
            model.project_gaussians(
                jnp.asarray(pos), *map(jnp.asarray, common), jnp.asarray(np.zeros(3, np.float32))
            )[4]
        )
        c_from_side = np.asarray(
            model.project_gaussians(
                jnp.asarray(pos),
                *map(jnp.asarray, common),
                jnp.asarray(np.array([10.0, 0.0, 5.0], np.float32)),
            )[4]
        )
        assert abs(c_from_origin[0, 0] - c_from_side[0, 0]) > 0.1

    def test_rotation_matrix_orthonormal(self):
        rng = np.random.default_rng(3)
        q = rng.normal(size=(16, 4)).astype(np.float32)
        q /= np.linalg.norm(q, axis=1, keepdims=True)
        r = np.asarray(model.quat_to_mat(jnp.asarray(q)))
        eye = r @ np.swapaxes(r, 1, 2)
        np.testing.assert_allclose(eye, np.tile(np.eye(3), (16, 1, 1)), atol=1e-5)


class TestWarp:
    def test_identity_warp_preserves_valid_pixels(self):
        h, w = 32, 48
        rng = np.random.default_rng(0)
        rgb = rng.uniform(0, 1, (h, w, 3)).astype(np.float32)
        depth = np.full((h, w), 4.0, np.float32)
        valid = np.ones((h, w), np.float32)
        out = model.warp_frame(
            *map(jnp.asarray, (rgb, depth, valid, np.eye(4, dtype=np.float32), intr6()))
        )
        rgb_t, depth_t, filled = map(np.asarray, out)
        assert filled.mean() > 0.99
        np.testing.assert_allclose(rgb_t, rgb, atol=1e-5)
        np.testing.assert_allclose(depth_t, 4.0, atol=1e-4)

    def test_translation_creates_holes_on_edge(self):
        h, w = 32, 48
        rgb = np.zeros((h, w, 3), np.float32)
        depth = np.full((h, w), 2.0, np.float32)
        valid = np.ones((h, w), np.float32)
        t = np.eye(4, dtype=np.float32)
        t[0, 3] = -0.1  # 15 px shift at depth 2 with fx=300
        out = model.warp_frame(*map(jnp.asarray, (rgb, depth, valid, t, intr6())))
        filled = np.asarray(out[2])
        assert filled.mean() < 0.99
        assert filled.mean() > 0.3

    def test_zbuffer_keeps_nearest(self):
        h, w = 16, 16
        rgb = np.zeros((h, w, 3), np.float32)
        rgb[:, :8] = [1.0, 0.0, 0.0]  # near content, left half
        rgb[:, 8:] = [0.0, 0.0, 1.0]
        depth = np.full((h, w), 10.0, np.float32)
        depth[:, :8] = 1.0
        valid = np.ones((h, w), np.float32)
        # Shift so halves collide: move camera left 1m; near shifts a lot.
        t = np.eye(4, dtype=np.float32)
        t[0, 3] = 1.0
        out = model.warp_frame(*map(jnp.asarray, (rgb, depth, valid, t, intr6(fx=8.0, fy=8.0, cx=8.0, cy=8.0))))
        rgb_t, depth_t, filled = map(np.asarray, out)
        # Wherever both land, red (near) must win.
        both = filled > 0.5
        red_region = rgb_t[both]
        assert (red_region[:, 0] >= red_region[:, 2] - 1e-5).sum() > 0.5 * len(red_region)

    def test_invalid_pixels_not_splatted(self):
        h, w = 16, 16
        rgb = np.ones((h, w, 3), np.float32)
        depth = np.full((h, w), 3.0, np.float32)
        valid = np.zeros((h, w), np.float32)
        out = model.warp_frame(
            *map(jnp.asarray, (rgb, depth, valid, np.eye(4, dtype=np.float32), intr6()))
        )
        filled = np.asarray(out[2])
        assert filled.max() == 0.0


class TestAot:
    def test_lowering_produces_hlo_text(self, tmp_path):
        manifest = aot.build_all(str(tmp_path), width=64, height=48)
        assert "rasterize_b16_k64" in manifest["artifacts"]
        assert "project_n4096" in manifest["artifacts"]
        assert f"warp_64x48" in manifest["artifacts"]
        for name, entry in manifest["artifacts"].items():
            text = (tmp_path / entry["file"]).read_text()
            assert text.startswith("HloModule"), f"{name} not HLO text"
            assert "ROOT" in text
        # manifest.json exists and is valid json
        import json

        m = json.loads((tmp_path / "manifest.json").read_text())
        assert m["tile"] == 16


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
