//! `cargo bench` — regenerates every table and figure of the paper's
//! evaluation (DESIGN.md per-experiment index) and writes the aggregate
//! JSON report to `bench_report.json`.
//!
//! Criterion is unavailable offline; this is a plain `harness = false`
//! binary over `ls_gaussian::bench`.
//!
//! Usage:
//!   cargo bench                         # everything, default scale
//!   cargo bench -- --exp fig14          # one experiment
//!   cargo bench -- --scale 0.3 --frames 15

use ls_gaussian::bench::{run_experiment, ExpOptions, ALL_EXPERIMENTS};
use ls_gaussian::util::cli::Args;
use ls_gaussian::util::json::Json;
use std::time::Instant;

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let opts = ExpOptions {
        scale: args.f32_or("scale", 0.35),
        width: args.usize_or("width", 320),
        height: args.usize_or("height", 192),
        frames: args.usize_or("frames", 10),
        window: args.usize_or("window", 5),
    };
    println!(
        "LS-Gaussian paper experiments | scale={} {}x{} frames={} window={}",
        opts.scale, opts.width, opts.height, opts.frames, opts.window
    );

    let ids: Vec<String> = match args.get("exp") {
        Some(id) => vec![id.to_string()],
        None => {
            let mut v: Vec<String> = ALL_EXPERIMENTS.iter().map(|s| s.to_string()).collect();
            v.push("tab1".to_string());
            v.push("streaming".to_string());
            v.push("sched".to_string());
            v.push("balance".to_string());
            v.push("fleet".to_string());
            v.push("kernels".to_string());
            v.push("qos".to_string());
            v.push("temporal".to_string());
            v
        }
    };

    let mut report = Json::obj();
    let mut meta = Json::obj();
    meta.set("scale", opts.scale)
        .set("width", opts.width)
        .set("height", opts.height)
        .set("frames", opts.frames)
        .set("window", opts.window);
    report.set("options", meta);

    for id in &ids {
        let t0 = Instant::now();
        match run_experiment(id, &opts) {
            Some(json) => {
                println!("[{id}] completed in {:.1}s", t0.elapsed().as_secs_f64());
                if id == "streaming" {
                    // Machine-readable steady-state record: the repo's
                    // streaming perf trajectory across PRs.
                    std::fs::write("BENCH_streaming.json", json.to_string_pretty())
                        .expect("writing BENCH_streaming.json");
                    println!("wrote BENCH_streaming.json");
                }
                if id == "sched" {
                    // Imbalanced-session pacing record (lockstep barrier
                    // vs deadline-paced scheduler).
                    std::fs::write("BENCH_sched.json", json.to_string_pretty())
                        .expect("writing BENCH_sched.json");
                    println!("wrote BENCH_sched.json");
                }
                if id == "balance" {
                    // Tile-dispatch record (naive index order vs
                    // workload-aware plan), gated alongside streaming.
                    std::fs::write("BENCH_balance.json", json.to_string_pretty())
                        .expect("writing BENCH_balance.json");
                    println!("wrote BENCH_balance.json");
                }
                if id == "fleet" {
                    // Multi-scene serving record (two scenes, one global
                    // residency budget), gated alongside streaming.
                    std::fs::write("BENCH_fleet.json", json.to_string_pretty())
                        .expect("writing BENCH_fleet.json");
                    println!("wrote BENCH_fleet.json");
                }
                if id == "kernels" {
                    // Per-pair kernel record (scalar vs 8-wide SIMD),
                    // gated alongside streaming.
                    std::fs::write("BENCH_kernels.json", json.to_string_pretty())
                        .expect("writing BENCH_kernels.json");
                    println!("wrote BENCH_kernels.json");
                }
                if id == "qos" {
                    // Closed-loop QoS overload record (controller off vs
                    // on + ladder PSNR floors), gated alongside streaming.
                    std::fs::write("BENCH_qos.json", json.to_string_pretty())
                        .expect("writing BENCH_qos.json");
                    println!("wrote BENCH_qos.json");
                }
                if id == "temporal" {
                    // Temporal plan-cache record (cache off vs on over a
                    // small-delta orbit creep), gated alongside streaming.
                    std::fs::write("BENCH_temporal.json", json.to_string_pretty())
                        .expect("writing BENCH_temporal.json");
                    println!("wrote BENCH_temporal.json");
                }
                report.set(id, json);
            }
            None => {
                eprintln!("unknown experiment '{id}'; known: {ALL_EXPERIMENTS:?} + tab1");
                std::process::exit(2);
            }
        }
    }

    let out = "bench_report.json";
    std::fs::write(out, report.to_string_pretty()).expect("writing report");
    println!("\nwrote {out}");

    // When the run was traced (LSG_TRACE=<path>), persist the Perfetto
    // timeline of everything above.
    if let Some(path) = ls_gaussian::telemetry::flush_trace() {
        println!("wrote stage trace to {} (load in ui.perfetto.dev)", path.display());
    }
}
